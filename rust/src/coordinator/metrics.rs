//! Lightweight metrics: counters, gauges and latency histograms for the
//! coordinator and service (std-only; exported in a Prometheus-like text
//! format by [`Metrics::render`], which is what the TCP `METRICS`
//! command returns — see `docs/PROTOCOL.md`).
//!
//! The coordinator publishes per-stage job timers through this type:
//! `queue_wait` (submit → picked up by the dispatcher), `dispatch`
//! (picked up → handed to the pool) and `run` (handoff → job complete,
//! including any wait in the pool's own backlog), plus gauge-style
//! occupancy counters (`jobs_queued`,
//! `jobs_running`, `replicas_inflight`) so pool saturation is observable
//! while a load test runs. `docs/ARCHITECTURE.md` shows where each timer
//! starts and stops.
//!
//! Concurrency: every histogram sits behind its own lock, and reads
//! ([`Metrics::quantile_us`], [`Metrics::mean_us`]) copy a consistent
//! snapshot (all buckets + the sample count) under that one lock before
//! computing. Readers therefore never see a half-applied `observe` from
//! another thread, and concurrent `observe` calls on *different*
//! histograms never contend — the shared name→histogram map is only
//! locked long enough to clone an `Arc`.

use std::collections::BTreeMap;
// std::sync::atomic (not crate::sync::atomic) by design: the registry
// relies on `Arc<AtomicU64>: Default` via `or_default()`, which loom's
// instrumented atomics don't provide, and metrics are never part of a
// loom model. This file is on the xtask lint-safety std-atomics
// allowlist; keep it in sync with docs/ARCHITECTURE.md if that changes.
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Fixed log-scale latency histogram (microseconds, powers of two up to
/// ~17 minutes).
const BUCKETS: usize = 30;

/// A named set of counters, gauges and histograms.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicI64>>>,
    histograms: Mutex<BTreeMap<String, Arc<Mutex<Histogram>>>>,
}

#[derive(Clone, Default)]
struct Histogram {
    counts: [u64; BUCKETS],
    total_us: u64,
    samples: u64,
}

impl Histogram {
    /// Bucket index for a microsecond value: `us` with `i` significant
    /// bits — i.e. `us` in `[2^(i-1), 2^i)` — lands in bucket `i`, whose
    /// reported bound `2^i` is an exclusive upper bound; 0 lands in
    /// bucket 0.
    fn bucket(us: u64) -> usize {
        (64 - us.leading_zeros() as usize).min(BUCKETS - 1)
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a counter.
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Add to a counter.
    pub fn add(&self, name: &str, v: u64) {
        let cell = {
            let mut map = self.counters.lock().unwrap();
            map.entry(name.to_string()).or_default().clone()
        };
        cell.fetch_add(v, Ordering::Relaxed);
    }

    /// Read a counter.
    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Move a gauge by `delta` (gauges go up *and* down — occupancy,
    /// queue depth, in-flight replicas).
    pub fn gauge_add(&self, name: &str, delta: i64) {
        let cell = {
            let mut map = self.gauges.lock().unwrap();
            map.entry(name.to_string()).or_default().clone()
        };
        cell.fetch_add(delta, Ordering::Relaxed);
    }

    /// Set a gauge to an absolute value (occupancy republished from an
    /// authoritative source — e.g. the registry's `registry_bytes` /
    /// `registry_entries`, recomputed under the registry lock).
    pub fn gauge_set(&self, name: &str, v: i64) {
        let cell = {
            let mut map = self.gauges.lock().unwrap();
            map.entry(name.to_string()).or_default().clone()
        };
        cell.store(v, Ordering::Relaxed);
    }

    /// Read a gauge (0 if never touched).
    pub fn gauge(&self, name: &str) -> i64 {
        self.gauges
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Record a latency observation.
    pub fn observe(&self, name: &str, d: std::time::Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        let hist = self.histogram(name);
        let mut h = hist.lock().unwrap();
        h.counts[Histogram::bucket(us)] += 1;
        h.total_us += us;
        h.samples += 1;
    }

    /// The shared handle for one named histogram (creating it empty on
    /// first use). The map lock is held only for this lookup, so
    /// concurrent observers of different series never serialize.
    fn histogram(&self, name: &str) -> Arc<Mutex<Histogram>> {
        let mut map = self.histograms.lock().unwrap();
        map.entry(name.to_string()).or_default().clone()
    }

    /// A consistent copy of one histogram: taken under the histogram's
    /// own lock so buckets and `samples` always agree, even mid-`observe`
    /// on another thread.
    fn snapshot(&self, name: &str) -> Option<Histogram> {
        let hist = self.histograms.lock().unwrap().get(name)?.clone();
        let snap = hist.lock().unwrap().clone();
        Some(snap)
    }

    /// Number of samples observed for a histogram.
    pub fn samples(&self, name: &str) -> u64 {
        self.snapshot(name).map(|h| h.samples).unwrap_or(0)
    }

    /// Mean latency in microseconds (None if unobserved).
    pub fn mean_us(&self, name: &str) -> Option<f64> {
        let h = self.snapshot(name)?;
        if h.samples == 0 {
            return None;
        }
        Some(h.total_us as f64 / h.samples as f64)
    }

    /// Approximate quantile from the log buckets: the upper bound of the
    /// bucket containing the q-th sample. `q` is clamped to `[0, 1]`,
    /// and any quantile of a non-empty series targets at least the first
    /// sample — so `quantile_us(name, 0.0)` is the (bucketed) minimum,
    /// never a phantom 1 µs from an empty prefix of buckets. Returns
    /// `None` for an unknown or empty series.
    ///
    /// The bucket walk runs on a snapshot taken under the histogram's
    /// lock, so a concurrent `observe` can never tear the read (buckets
    /// from one state, `samples` from another).
    ///
    /// ```
    /// use snowball::coordinator::Metrics;
    /// use std::time::Duration;
    ///
    /// let m = Metrics::new();
    /// assert_eq!(m.quantile_us("lat", 0.5), None); // unobserved series
    ///
    /// m.observe("lat", Duration::from_micros(100));
    /// // One sample: every quantile is that sample's bucket bound.
    /// let p0 = m.quantile_us("lat", 0.0).unwrap();
    /// assert_eq!(p0, 128); // 100 µs falls in the [64, 128) bucket
    /// assert_eq!(m.quantile_us("lat", 0.5), Some(p0));
    /// assert_eq!(m.quantile_us("lat", 1.0), Some(p0));
    /// ```
    pub fn quantile_us(&self, name: &str, q: f64) -> Option<u64> {
        let h = self.snapshot(name)?;
        if h.samples == 0 {
            return None;
        }
        Some(quantile_of(&h, q))
    }

    /// Text rendering (for the service's METRICS command): one line per
    /// series — `counter <name> <v>`, `gauge <name> <v>`, and
    /// `histogram <name> samples=<n> mean_us=<f> p50_us=<v> p99_us=<v>`.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} {}\n", v.load(Ordering::Relaxed)));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("gauge {k} {}\n", v.load(Ordering::Relaxed)));
        }
        let hists: Vec<(String, Arc<Mutex<Histogram>>)> = {
            let map = self.histograms.lock().unwrap();
            map.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        for (k, hist) in hists {
            let h = hist.lock().unwrap().clone();
            let mean = if h.samples == 0 { 0.0 } else { h.total_us as f64 / h.samples as f64 };
            let (p50, p99) = (quantile_of(&h, 0.5), quantile_of(&h, 0.99));
            out.push_str(&format!(
                "histogram {k} samples={} mean_us={mean:.1} p50_us={p50} p99_us={p99}\n",
                h.samples
            ));
        }
        out
    }
}

/// Quantile on an already-snapshotted histogram (0 if empty).
fn quantile_of(h: &Histogram, q: f64) -> u64 {
    if h.samples == 0 {
        return 0;
    }
    let target = ((q.clamp(0.0, 1.0) * h.samples as f64).ceil() as u64).max(1);
    let mut acc = 0;
    for (i, &c) in h.counts.iter().enumerate() {
        acc += c;
        if acc >= target {
            return 1u64 << i;
        }
    }
    1u64 << (BUCKETS - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("jobs");
        m.add("jobs", 4);
        assert_eq!(m.get("jobs"), 5);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn gauges_move_both_ways() {
        let m = Metrics::new();
        m.gauge_add("inflight", 3);
        m.gauge_add("inflight", -2);
        assert_eq!(m.gauge("inflight"), 1);
        assert_eq!(m.gauge("missing"), 0);
        m.gauge_set("inflight", 40);
        assert_eq!(m.gauge("inflight"), 40);
        m.gauge_set("fresh", -7);
        assert_eq!(m.gauge("fresh"), -7);
    }

    #[test]
    fn histogram_mean_and_quantile() {
        let m = Metrics::new();
        for us in [100u64, 200, 400, 800] {
            m.observe("lat", Duration::from_micros(us));
        }
        let mean = m.mean_us("lat").unwrap();
        assert!((mean - 375.0).abs() < 1.0);
        let p50 = m.quantile_us("lat", 0.5).unwrap();
        assert!(p50 >= 128 && p50 <= 512, "p50 bucket {p50}");
        assert!(m.quantile_us("lat", 1.0).unwrap() >= 800);
    }

    #[test]
    fn empty_and_one_sample_quantiles() {
        let m = Metrics::new();
        // Unknown series: None at every q.
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(m.quantile_us("lat", q), None);
        }
        assert_eq!(m.mean_us("lat"), None);
        assert_eq!(m.samples("lat"), 0);
        // One sample far above the first bucket: q=0 must report that
        // sample's bucket, not the phantom 1 µs bucket-0 bound the old
        // `target = ceil(0·n) = 0` walk produced.
        m.observe("lat", Duration::from_micros(800));
        for q in [-1.0, 0.0, 0.5, 1.0, 2.0] {
            assert_eq!(m.quantile_us("lat", q), Some(1024), "q={q}");
        }
        assert_eq!(m.samples("lat"), 1);
    }

    /// Readers racing writers must always see a consistent snapshot:
    /// whatever interleaving happens, a quantile of a non-empty series
    /// is one of the bucket bounds actually observed.
    #[test]
    fn concurrent_observe_and_quantile_snapshot() {
        let m = Arc::new(Metrics::new());
        let writers: Vec<_> = (0..4)
            .map(|w| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for i in 0..500u64 {
                        m.observe("lat", Duration::from_micros(100 + (w * 500 + i) % 700));
                    }
                })
            })
            .collect();
        let reader = {
            let m = m.clone();
            std::thread::spawn(move || {
                for _ in 0..200 {
                    if let Some(p) = m.quantile_us("lat", 0.99) {
                        // All samples live in [100, 800) µs → buckets 7..=10.
                        assert!(p >= 128 && p <= 1024, "torn quantile {p}");
                    }
                }
            })
        };
        for w in writers {
            w.join().unwrap();
        }
        reader.join().unwrap();
        assert_eq!(m.samples("lat"), 2000);
        assert_eq!(m.quantile_us("lat", 1.0), Some(1024));
    }

    #[test]
    fn render_lists_everything() {
        let m = Metrics::new();
        m.inc("a");
        m.gauge_add("g", 2);
        m.observe("b", Duration::from_micros(10));
        let r = m.render();
        assert!(r.contains("counter a 1"));
        assert!(r.contains("gauge g 2"));
        assert!(r.contains("histogram b samples=1"));
        assert!(r.contains("p99_us=16"), "render should include quantiles: {r}");
    }
}
