//! Lightweight metrics: counters and latency histograms for the
//! coordinator and service (std-only; exported in a Prometheus-like text
//! format by `render`).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Fixed log-scale latency histogram (microseconds, powers of two up to
/// ~17 minutes).
const BUCKETS: usize = 30;

/// A named set of counters and histograms.
#[derive(Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, AtomicU64>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

#[derive(Default)]
struct Histogram {
    counts: [u64; BUCKETS],
    total_us: u64,
    samples: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a counter.
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Add to a counter.
    pub fn add(&self, name: &str, v: u64) {
        let mut map = self.counters.lock().unwrap();
        map.entry(name.to_string()).or_default().fetch_add(v, Ordering::Relaxed);
    }

    /// Read a counter.
    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Record a latency observation.
    pub fn observe(&self, name: &str, d: std::time::Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        let bucket = (64 - us.leading_zeros() as usize).min(BUCKETS - 1);
        let mut map = self.histograms.lock().unwrap();
        let h = map.entry(name.to_string()).or_default();
        h.counts[bucket] += 1;
        h.total_us += us;
        h.samples += 1;
    }

    /// Mean latency in microseconds (None if unobserved).
    pub fn mean_us(&self, name: &str) -> Option<f64> {
        let map = self.histograms.lock().unwrap();
        let h = map.get(name)?;
        if h.samples == 0 {
            return None;
        }
        Some(h.total_us as f64 / h.samples as f64)
    }

    /// Approximate quantile from the log buckets (upper bound of the
    /// bucket containing the q-th sample).
    pub fn quantile_us(&self, name: &str, q: f64) -> Option<u64> {
        let map = self.histograms.lock().unwrap();
        let h = map.get(name)?;
        if h.samples == 0 {
            return None;
        }
        let target = (q * h.samples as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in h.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Some(1u64 << i);
            }
        }
        Some(1u64 << (BUCKETS - 1))
    }

    /// Text rendering (for the service's METRICS command).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} {}\n", v.load(Ordering::Relaxed)));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            let mean = if h.samples == 0 { 0.0 } else { h.total_us as f64 / h.samples as f64 };
            out.push_str(&format!("histogram {k} samples={} mean_us={mean:.1}\n", h.samples));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("jobs");
        m.add("jobs", 4);
        assert_eq!(m.get("jobs"), 5);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn histogram_mean_and_quantile() {
        let m = Metrics::new();
        for us in [100u64, 200, 400, 800] {
            m.observe("lat", Duration::from_micros(us));
        }
        let mean = m.mean_us("lat").unwrap();
        assert!((mean - 375.0).abs() < 1.0);
        let p50 = m.quantile_us("lat", 0.5).unwrap();
        assert!(p50 >= 128 && p50 <= 512, "p50 bucket {p50}");
        assert!(m.quantile_us("lat", 1.0).unwrap() >= 800);
    }

    #[test]
    fn render_lists_everything() {
        let m = Metrics::new();
        m.inc("a");
        m.observe("b", Duration::from_micros(10));
        let r = m.render();
        assert!(r.contains("counter a 1"));
        assert!(r.contains("histogram b samples=1"));
    }
}
