//! L3 coordinator: size-classed admission queue, overlapping job
//! dispatch over the shared replica pool, metrics and the TCP service
//! (`docs/ARCHITECTURE.md` has the full layer diagram and data flow;
//! `docs/PROTOCOL.md` specifies the wire protocol).
//!
//! The coordinator owns the machine: callers submit [`job::JobSpec`]s;
//! a background dispatcher drains the queue and fans work over the
//! [`scheduler::ReplicaScheduler`] thread pool, then publishes
//! [`job::JobResult`]s. Two dispatch modes exist
//! ([`DispatchMode`]):
//!
//! * **Overlapping** (default): the dispatcher drains *all* queued jobs
//!   at once, groups them by instance size class ([`batcher::plan`], so
//!   small jobs ride one fan-out together) and enqueues every replica
//!   of every job as its own pool work item. Replicas of different jobs
//!   interleave on the workers, so the pool never idles between jobs —
//!   the software analogue of keeping the FPGA's replica lanes
//!   saturated under multi-tenant load.
//! * **Serial**: one job at a time, strict FIFO — the reference
//!   semantics and the baseline the load harness
//!   (`rust/tests/service_load.rs`, `BENCH_service.json`) compares
//!   against.
//!
//! Determinism is unchanged by the mode: every replica stream is a pure
//! function of `StatelessRng::new(spec.seed).child(replica)`, so a
//! job's result vector is bit-identical under serial, overlapping, or
//! any worker count (pinned by `rust/tests/pool_determinism.rs` and
//! `rust/tests/service_load.rs`).
//!
//! Per-stage timers land in [`metrics::Metrics`] under `queue_wait`
//! (submit → picked up), `dispatch` (picked up → handed to the pool),
//! `run` (handoff → job complete) and `job_wall` (submit → complete),
//! with occupancy gauges `jobs_queued` / `jobs_running` /
//! `replicas_inflight` — all visible through the TCP `METRICS` command.

pub mod batcher;
pub mod job;
pub mod metrics;
pub mod scheduler;
pub mod service;

pub use job::{Backend, JobResult, JobSpec, JobState, ReplicaResult};
pub use metrics::Metrics;
pub use scheduler::ReplicaScheduler;
pub use service::Service;

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// How the dispatcher feeds the pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchMode {
    /// One job at a time, strict FIFO; the next job starts only after
    /// every replica of the previous one finished. Reference semantics
    /// and the load-test baseline.
    Serial,
    /// Drain the whole admission queue, group jobs by size class and
    /// enqueue every replica as an independent pool work item, so many
    /// jobs execute concurrently over the shared pool.
    Overlapping,
}

/// Coordinator configuration (see [`Coordinator::start_with`]).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Compute threads in the replica pool (0 = one per CPU).
    pub workers: usize,
    /// Dispatch strategy; [`DispatchMode::Overlapping`] unless you need
    /// the serial baseline.
    pub mode: DispatchMode,
    /// Instance-size classes for admission batching
    /// ([`batcher::DEFAULT_CLASSES`] by default).
    pub classes: Vec<usize>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            mode: DispatchMode::Overlapping,
            classes: batcher::DEFAULT_CLASSES.to_vec(),
        }
    }
}

/// A job waiting in the admission queue.
struct Queued {
    id: u64,
    spec: JobSpec,
    submitted: Instant,
}

/// Shared coordinator state.
struct Inner {
    queue: Mutex<VecDeque<Queued>>,
    queue_cv: Condvar,
    states: Mutex<HashMap<u64, JobState>>,
    /// Signalled (under the `states` lock) whenever a job reaches a
    /// terminal state, so `wait` latency is bounded by scheduling, not a
    /// poll interval.
    state_cv: Condvar,
    results: Mutex<HashMap<u64, JobResult>>,
    next_id: Mutex<u64>,
    shutdown: Mutex<bool>,
    /// Jobs handed to the pool but not yet complete (overlapping mode);
    /// `shutdown` drains this to zero before the dispatcher exits.
    inflight: Mutex<usize>,
    inflight_cv: Condvar,
}

/// The job coordinator. Cloneable handle; `Drop` of the last handle does
/// not stop the dispatcher — call [`Coordinator::shutdown`].
#[derive(Clone)]
pub struct Coordinator {
    inner: Arc<Inner>,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start a coordinator with `workers` compute threads (0 = auto),
    /// overlapping dispatch, and a background dispatcher thread.
    pub fn start(workers: usize) -> Self {
        Self::start_with(CoordinatorConfig { workers, ..Default::default() })
    }

    /// Start a coordinator with the serial (one-job-at-a-time) dispatcher
    /// — the reference baseline the load harness compares against.
    pub fn start_serial(workers: usize) -> Self {
        Self::start_with(CoordinatorConfig {
            workers,
            mode: DispatchMode::Serial,
            ..Default::default()
        })
    }

    /// Start a coordinator with an explicit [`CoordinatorConfig`].
    pub fn start_with(cfg: CoordinatorConfig) -> Self {
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            states: Mutex::new(HashMap::new()),
            state_cv: Condvar::new(),
            results: Mutex::new(HashMap::new()),
            next_id: Mutex::new(1),
            shutdown: Mutex::new(false),
            inflight: Mutex::new(0),
            inflight_cv: Condvar::new(),
        });
        let metrics = Arc::new(Metrics::new());
        let c = Self { inner: inner.clone(), metrics: metrics.clone() };
        let dispatcher = c.clone();
        std::thread::Builder::new()
            .name("snowball-dispatch".into())
            .spawn(move || dispatcher.dispatch_loop(cfg))
            .expect("spawn dispatcher");
        c
    }

    /// Submit a job; returns its id immediately. The job queues until
    /// the dispatcher picks it up (time spent there is the `queue_wait`
    /// histogram).
    ///
    /// ```
    /// use snowball::coordinator::{Backend, Coordinator, JobSpec};
    /// use snowball::engine::{Mode, Schedule, SelectorKind};
    /// use snowball::graph::generators;
    /// use snowball::problems::MaxCut;
    /// use snowball::rng::StatelessRng;
    /// use std::sync::Arc;
    ///
    /// let coord = Coordinator::start(2);
    /// let rng = StatelessRng::new(1);
    /// let problem = MaxCut::new(generators::erdos_renyi(16, 40, &[-1, 1], &rng));
    /// let id = coord.submit(JobSpec {
    ///     model: Arc::new(problem.model().clone()),
    ///     label: "doc".into(),
    ///     mode: Mode::RouletteWheel,
    ///     selector: SelectorKind::Fenwick,
    ///     schedule: Schedule::Geometric { t0: 4.0, t1: 0.1 },
    ///     steps: 200,
    ///     replicas: 2,
    ///     seed: 7,
    ///     target_energy: None,
    ///     backend: Backend::Native,
    /// });
    /// let result = coord.wait(id).expect("job completes");
    /// assert_eq!(result.replicas.len(), 2);
    /// coord.shutdown();
    /// ```
    pub fn submit(&self, spec: JobSpec) -> u64 {
        let id = {
            let mut next = self.inner.next_id.lock().unwrap();
            let id = *next;
            *next += 1;
            id
        };
        self.inner.states.lock().unwrap().insert(id, JobState::Queued);
        self.inner
            .queue
            .lock()
            .unwrap()
            .push_back(Queued { id, spec, submitted: Instant::now() });
        self.inner.queue_cv.notify_one();
        self.metrics.inc("jobs_submitted");
        self.metrics.gauge_add("jobs_queued", 1);
        id
    }

    /// Current state of a job (None = unknown id).
    pub fn state(&self, id: u64) -> Option<JobState> {
        self.inner.states.lock().unwrap().get(&id).cloned()
    }

    /// Result of a finished job.
    pub fn result(&self, id: u64) -> Option<JobResult> {
        self.inner.results.lock().unwrap().get(&id).cloned()
    }

    /// Block until the job finishes (or fails); returns its result, or
    /// `None` for an unknown id or a failed job. Condvar-notified on
    /// every terminal transition — no poll loop, so wait latency is not
    /// quantized to a sleep interval.
    ///
    /// ```
    /// use snowball::coordinator::Coordinator;
    ///
    /// let coord = Coordinator::start(1);
    /// assert!(coord.wait(999).is_none()); // unknown id: immediate None
    /// coord.shutdown();
    /// ```
    pub fn wait(&self, id: u64) -> Option<JobResult> {
        let mut states = self.inner.states.lock().unwrap();
        loop {
            match states.get(&id) {
                None => return None,
                Some(JobState::Done) => {
                    drop(states);
                    return self.result(id);
                }
                Some(JobState::Failed(_)) => return None,
                Some(_) => states = self.inner.state_cv.wait(states).unwrap(),
            }
        }
    }

    /// Stop the dispatcher: queued jobs still drain, in-flight jobs
    /// complete, then the dispatcher thread exits.
    pub fn shutdown(&self) {
        *self.inner.shutdown.lock().unwrap() = true;
        self.inner.queue_cv.notify_all();
    }

    /// Publish a finished job: result map, terminal state, stage timers.
    /// Runs on the dispatcher thread (serial mode) or on the pool thread
    /// that completed the job's last replica (overlapping mode).
    fn complete(
        &self,
        id: u64,
        label: String,
        replicas: Vec<ReplicaResult>,
        submitted: Instant,
        run_start: Instant,
    ) {
        let result = JobResult { job_id: id, label, replicas, wall: run_start.elapsed() };
        self.metrics.observe("run", result.wall);
        self.metrics.observe("job_wall", submitted.elapsed());
        self.metrics.inc("jobs_done");
        self.metrics.gauge_add("jobs_running", -1);
        self.inner.results.lock().unwrap().insert(id, result);
        self.inner.states.lock().unwrap().insert(id, JobState::Done);
        self.inner.state_cv.notify_all();
    }

    fn dispatch_loop(&self, cfg: CoordinatorConfig) {
        let scheduler = Arc::new(ReplicaScheduler::new(cfg.workers));
        loop {
            // Drain every queued job in one go: the batch is what the
            // size-class planner groups.
            let mut batch: Vec<Option<Queued>> = {
                let mut q = self.inner.queue.lock().unwrap();
                loop {
                    if !q.is_empty() {
                        break q.drain(..).map(Some).collect();
                    }
                    if *self.inner.shutdown.lock().unwrap() {
                        drop(q);
                        // Let in-flight overlapping jobs finish before the
                        // scheduler (and its pool) is torn down.
                        let mut inflight = self.inner.inflight.lock().unwrap();
                        while *inflight > 0 {
                            inflight = self.inner.inflight_cv.wait(inflight).unwrap();
                        }
                        return;
                    }
                    let (guard, _) = self
                        .inner
                        .queue_cv
                        .wait_timeout(q, std::time::Duration::from_millis(50))
                        .unwrap();
                    q = guard;
                }
            };
            // Dispatch order: serial keeps strict FIFO (it is the
            // reference baseline); overlapping walks the batcher's size
            // groups in ascending class order so each class's jobs enter
            // the pool together, then takes the overflow.
            let order: Vec<usize> = match cfg.mode {
                DispatchMode::Serial => (0..batch.len()).collect(),
                DispatchMode::Overlapping => {
                    let sizes: Vec<usize> =
                        batch.iter().map(|b| b.as_ref().unwrap().spec.model.len()).collect();
                    let plan = batcher::plan(&sizes, &cfg.classes);
                    let groups = plan.groups();
                    self.metrics.add("batch_groups", groups.len() as u64);
                    self.metrics.add("batch_overflow_jobs", plan.overflow.len() as u64);
                    groups
                        .into_iter()
                        .flat_map(|(_, jobs)| jobs)
                        .chain(plan.overflow.iter().copied())
                        .collect()
                }
            };
            for idx in order {
                let Queued { id, spec, submitted } = batch[idx].take().expect("each job once");
                let picked_up = Instant::now();
                self.metrics.observe("queue_wait", submitted.elapsed());
                self.metrics.gauge_add("jobs_queued", -1);
                self.inner.states.lock().unwrap().insert(id, JobState::Running);
                self.metrics.gauge_add("jobs_running", 1);
                // The XLA backend is driven synchronously by callers that
                // own a runtime (examples/k2000_tts.rs); queued jobs fall
                // back to native execution so the service never needs a
                // PJRT client it might not have.
                match cfg.mode {
                    DispatchMode::Serial => {
                        self.metrics.observe("dispatch", picked_up.elapsed());
                        let run_start = Instant::now();
                        let replicas = scheduler.run_native(&spec);
                        self.complete(id, spec.label.clone(), replicas, submitted, run_start);
                    }
                    DispatchMode::Overlapping => {
                        *self.inner.inflight.lock().unwrap() += 1;
                        self.metrics.gauge_add("replicas_inflight", spec.replicas as i64);
                        let label = spec.label.clone();
                        let this = self.clone();
                        let occupancy = self.metrics.clone();
                        // Observe before handing off: a tiny job may
                        // complete (and wake waiters) the moment it is
                        // spawned, and by then its dispatch sample must
                        // already be visible.
                        self.metrics.observe("dispatch", picked_up.elapsed());
                        let run_start = Instant::now();
                        scheduler.spawn_native(
                            Arc::new(spec),
                            move || occupancy.gauge_add("replicas_inflight", -1),
                            move |replicas| {
                                this.complete(id, label, replicas, submitted, run_start);
                                let mut inflight = this.inner.inflight.lock().unwrap();
                                *inflight -= 1;
                                this.inner.inflight_cv.notify_all();
                            },
                        );
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Mode, Schedule, SelectorKind};
    use crate::graph::generators;
    use crate::problems::MaxCut;
    use crate::rng::StatelessRng;

    fn spec(label: &str, seed: u64) -> JobSpec {
        let rng = StatelessRng::new(seed);
        let p = MaxCut::new(generators::erdos_renyi(32, 120, &[-1, 1], &rng));
        JobSpec {
            model: Arc::new(p.model().clone()),
            label: label.into(),
            mode: Mode::RouletteWheel,
            selector: SelectorKind::Fenwick,
            schedule: Schedule::Geometric { t0: 5.0, t1: 0.05 },
            steps: 400,
            replicas: 4,
            seed,
            target_energy: None,
            backend: Backend::Native,
        }
    }

    #[test]
    fn submit_wait_result_lifecycle() {
        let c = Coordinator::start(2);
        let id = c.submit(spec("a", 1));
        let r = c.wait(id).expect("job should finish");
        assert_eq!(r.job_id, id);
        assert_eq!(r.replicas.len(), 4);
        assert_eq!(c.state(id), Some(JobState::Done));
        assert_eq!(c.metrics.get("jobs_done"), 1);
        c.shutdown();
    }

    #[test]
    fn multiple_jobs_fifo_and_isolated() {
        let c = Coordinator::start(2);
        let id1 = c.submit(spec("one", 1));
        let id2 = c.submit(spec("two", 2));
        let r1 = c.wait(id1).unwrap();
        let r2 = c.wait(id2).unwrap();
        assert_eq!(r1.label, "one");
        assert_eq!(r2.label, "two");
        assert_ne!(
            r1.replicas.iter().map(|r| r.best_energy).collect::<Vec<_>>(),
            r2.replicas.iter().map(|r| r.best_energy).collect::<Vec<_>>(),
        );
        c.shutdown();
    }

    /// Several threads blocked in `wait` on the same job must all be
    /// woken by the terminal-state notification (no poll loop involved).
    #[test]
    fn concurrent_waiters_all_notified() {
        let c = Coordinator::start(2);
        let id = c.submit(spec("shared", 7));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || c.wait(id).map(|r| r.job_id))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), Some(id));
        }
        c.shutdown();
    }

    #[test]
    fn unknown_job_is_none() {
        let c = Coordinator::start(1);
        assert!(c.state(999).is_none());
        assert!(c.result(999).is_none());
        assert!(c.wait(999).is_none());
        c.shutdown();
    }

    /// Serial and overlapping dispatch must produce identical per-job
    /// results (same replicas, energies, flips) for the same specs.
    #[test]
    fn overlapping_matches_serial_dispatch_results() {
        let key = |r: &JobResult| -> Vec<(u32, i64, u64)> {
            r.replicas.iter().map(|p| (p.replica, p.best_energy, p.flips)).collect()
        };
        let run = |c: Coordinator| -> Vec<Vec<(u32, i64, u64)>> {
            let ids: Vec<u64> = (0..5).map(|k| c.submit(spec(&format!("j{k}"), 50 + k))).collect();
            let out = ids.iter().map(|&id| key(&c.wait(id).unwrap())).collect();
            c.shutdown();
            out
        };
        let serial = run(Coordinator::start_serial(3));
        let overlapping = run(Coordinator::start(3));
        assert_eq!(serial, overlapping, "dispatch mode must not change results");
    }

    /// The per-stage timers and occupancy gauges must be live after a
    /// batch of jobs drains, and occupancy must return to zero.
    #[test]
    fn stage_timers_and_gauges_are_published() {
        let c = Coordinator::start(2);
        let ids: Vec<u64> = (0..4).map(|k| c.submit(spec(&format!("m{k}"), 80 + k))).collect();
        for id in ids {
            c.wait(id).unwrap();
        }
        for series in ["queue_wait", "dispatch", "run", "job_wall"] {
            assert_eq!(c.metrics.samples(series), 4, "{series} should have one sample per job");
            assert!(c.metrics.quantile_us(series, 0.99).is_some());
        }
        assert_eq!(c.metrics.get("jobs_done"), 4);
        assert_eq!(c.metrics.gauge("jobs_queued"), 0);
        assert_eq!(c.metrics.gauge("jobs_running"), 0);
        assert_eq!(c.metrics.gauge("replicas_inflight"), 0);
        c.shutdown();
    }

    /// `shutdown` must drain queued + in-flight jobs before the
    /// dispatcher (and its pool) goes away: anything submitted before
    /// the call still completes.
    #[test]
    fn shutdown_drains_inflight_jobs() {
        let c = Coordinator::start(2);
        let ids: Vec<u64> = (0..6).map(|k| c.submit(spec(&format!("d{k}"), 200 + k))).collect();
        c.shutdown();
        for id in ids {
            assert!(c.wait(id).is_some(), "job {id} must survive shutdown draining");
        }
    }
}
