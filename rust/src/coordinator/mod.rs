//! L3 coordinator: size-classed admission queue, overlapping job
//! dispatch over the shared replica pool, metrics and the TCP service
//! (`docs/ARCHITECTURE.md` has the full layer diagram and data flow;
//! `docs/PROTOCOL.md` specifies the wire protocol).
//!
//! The coordinator owns the machine: callers submit [`job::JobSpec`]s;
//! a background dispatcher drains the queue and fans work over the
//! [`scheduler::ReplicaScheduler`] thread pool, then publishes
//! [`job::JobResult`]s. Two dispatch modes exist
//! ([`DispatchMode`]):
//!
//! * **Overlapping** (default): the dispatcher drains *all* queued jobs
//!   at once, groups them by instance size class ([`batcher::plan`], so
//!   small jobs ride one fan-out together) and enqueues every replica
//!   of every job as its own pool work item. Replicas of different jobs
//!   interleave on the workers, so the pool never idles between jobs —
//!   the software analogue of keeping the FPGA's replica lanes
//!   saturated under multi-tenant load.
//! * **Serial**: one job at a time, strict FIFO — the reference
//!   semantics and the baseline the load harness
//!   (`rust/tests/service_load.rs`, `BENCH_service.json`) compares
//!   against.
//!
//! Determinism is unchanged by the mode: every replica stream is a pure
//! function of `StatelessRng::new(spec.seed).child(replica)`, so a
//! job's result vector is bit-identical under serial, overlapping, or
//! any worker count (pinned by `rust/tests/pool_determinism.rs` and
//! `rust/tests/service_load.rs`).
//!
//! **Admission control**: [`CoordinatorConfig::max_inflight_replicas`]
//! caps the in-flight replica *units* — each job weighs
//! `replicas × effective shard lanes`, so a sharded job is charged for
//! every thread it will occupy. The dispatcher *parks* (defers
//! dispatching, visible in the `dispatch` timer) while the cap is
//! reached, so a burst of huge jobs drains the pool before the next
//! one enters instead of starving small jobs for unbounded time; with
//! [`CoordinatorConfig::reject_when_saturated`] the service-facing
//! [`Coordinator::try_submit`] additionally refuses new work outright
//! (`ERR saturated …` on the wire) while the committed replica count
//! exceeds the cap.
//!
//! **Failure path**: replica panics are caught at the scheduler's work
//! item boundary; the job flips to [`JobState::Failed`] (message
//! preserved), its waiters are woken, and the dispatcher, the pool and
//! every other job carry on.
//!
//! Per-stage timers land in [`metrics::Metrics`] under `queue_wait`
//! (submit → picked up), `dispatch` (picked up → handed to the pool),
//! `run` (handoff → job complete) and `job_wall` (submit → complete),
//! with occupancy gauges `jobs_queued` / `jobs_running` /
//! `replicas_inflight` — all visible through the TCP `METRICS` command.

pub mod batcher;
pub mod deadline;
pub mod job;
pub mod journal;
pub mod metrics;
pub mod registry;
pub mod router;
pub mod scheduler;
pub mod service;

pub use job::{Backend, JobResult, JobSpec, JobState, PortfolioOutcome, ReplicaResult};
pub use journal::{JobCtl, JobJournal};
pub use metrics::Metrics;
pub use registry::{ModelHash, PutError, Registry, RegistryStats};
pub use router::Router;
pub use scheduler::ReplicaScheduler;
pub use service::Service;

use crate::stop::{StopCause, StopToken};
use deadline::DeadlineWheel;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// How the dispatcher feeds the pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchMode {
    /// One job at a time, strict FIFO; the next job starts only after
    /// every replica of the previous one finished. Reference semantics
    /// and the load-test baseline.
    Serial,
    /// Drain the whole admission queue, group jobs by size class and
    /// enqueue every replica as an independent pool work item, so many
    /// jobs execute concurrently over the shared pool.
    Overlapping,
}

/// Coordinator configuration (see [`Coordinator::start_with`]).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Compute threads in the replica pool (0 = one per CPU).
    pub workers: usize,
    /// Dispatch strategy; [`DispatchMode::Overlapping`] unless you need
    /// the serial baseline.
    pub mode: DispatchMode,
    /// Instance-size classes for admission batching
    /// ([`batcher::DEFAULT_CLASSES`] by default).
    pub classes: Vec<usize>,
    /// Cap on in-flight replica *units* (0 = unbounded), where a job
    /// weighs `replicas × shard lanes` — so sharded jobs are charged
    /// for every thread they will occupy. The overlapping dispatcher
    /// parks at the cap; a single job heavier than the cap still runs,
    /// but only alone.
    pub max_inflight_replicas: usize,
    /// With a nonzero cap: make [`Coordinator::try_submit`] refuse new
    /// jobs while the committed (queued + running) replica count
    /// exceeds the cap, instead of parking them in the queue.
    pub reject_when_saturated: bool,
    /// How long [`Coordinator::shutdown`] lets in-flight jobs keep
    /// running before preempting them ([`StopCause::Shutdown`] →
    /// `JobState::Cancelled` with a partial result). `0` (the default)
    /// is the legacy drain: shutdown waits for every job, however
    /// long it runs.
    pub shutdown_grace_ms: u64,
    /// Content-addressed model store backing `PUT` / `SOLVE model=`.
    /// `None` (the default) gives the coordinator a private registry
    /// with default capacity; the dispatch-tier [`Router`] passes
    /// `Some` so every worker shares one store and one `Arc` per model
    /// (docs/ARCHITECTURE.md § Registry & dispatch tier).
    pub registry: Option<Arc<Registry>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            mode: DispatchMode::Overlapping,
            classes: batcher::DEFAULT_CLASSES.to_vec(),
            max_inflight_replicas: 0,
            reject_when_saturated: false,
            shutdown_grace_ms: 0,
            registry: None,
        }
    }
}

/// Why [`Coordinator::try_submit`] refused a job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmissionError {
    /// Admitting the job would push the committed replica units
    /// (`replicas × shard lanes` per job) over the configured cap.
    Saturated {
        /// Replica units committed (queued + running) at refusal time.
        committed: usize,
        /// The configured `max_inflight_replicas`.
        cap: usize,
    },
    /// The dispatch tier has no live workers left to place the job on
    /// (every worker was [`Router::kill_worker`]ed).
    NoLiveWorkers,
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::Saturated { committed, cap } => write!(
                f,
                "saturated: {committed} replica units already committed, job would exceed \
                 cap {cap}; retry later"
            ),
            AdmissionError::NoLiveWorkers => {
                write!(f, "no live workers to accept the job")
            }
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Outcome of a bounded [`Coordinator::wait_for`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WaitOutcome {
    /// The job reached this terminal state within the window.
    Terminal(JobState),
    /// Still queued / running when the window closed.
    Pending,
    /// No job with that id.
    Unknown,
}

/// The submission surface the TCP [`Service`] drives — implemented by a
/// single [`Coordinator`] and by the multi-worker [`Router`], so one
/// generic service front-end serves both a standalone machine and a
/// dispatch tier. Semantics of each method match the identically named
/// [`Coordinator`] method.
pub trait Dispatch: Clone + Send + 'static {
    /// Admission-controlled submit ([`Coordinator::try_submit`]
    /// semantics). `hash` is `Some` when `spec.model` came out of a
    /// [`Registry::checkout`]: on `Ok` the implementation takes over
    /// that checkout pin (released when the job goes terminal); on
    /// `Err` the pin stays with the caller, who must unpin.
    fn submit_spec(&self, spec: JobSpec, hash: Option<ModelHash>) -> Result<u64, AdmissionError>;
    /// Request cooperative cancellation ([`Coordinator::cancel`]).
    fn cancel(&self, id: u64) -> bool;
    /// Current state of a job ([`Coordinator::state`]).
    fn state(&self, id: u64) -> Option<JobState>;
    /// Result of a finished job ([`Coordinator::result`]).
    fn result(&self, id: u64) -> Option<JobResult>;
    /// Bounded wait for a terminal state ([`Coordinator::wait_for`]).
    fn wait_for(&self, id: u64, timeout: Duration) -> WaitOutcome;
    /// The metrics sink the `METRICS` command renders.
    fn metrics(&self) -> &Metrics;
    /// The content-addressed model store `PUT` / `REGISTRY` /
    /// `SOLVE model=` drive.
    fn registry(&self) -> &Arc<Registry>;
    /// Stop the machine ([`Coordinator::shutdown`]).
    fn shutdown(&self);
}

impl Dispatch for Coordinator {
    fn submit_spec(&self, spec: JobSpec, hash: Option<ModelHash>) -> Result<u64, AdmissionError> {
        self.try_submit_inner(spec, true, None, hash)
    }

    fn cancel(&self, id: u64) -> bool {
        Coordinator::cancel(self, id)
    }

    fn state(&self, id: u64) -> Option<JobState> {
        Coordinator::state(self, id)
    }

    fn result(&self, id: u64) -> Option<JobResult> {
        Coordinator::result(self, id)
    }

    fn wait_for(&self, id: u64, timeout: Duration) -> WaitOutcome {
        Coordinator::wait_for(self, id, timeout)
    }

    fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn registry(&self) -> &Arc<Registry> {
        Coordinator::registry(self)
    }

    fn shutdown(&self) {
        Coordinator::shutdown(self)
    }
}

/// A job waiting in the admission queue.
struct Queued {
    id: u64,
    spec: JobSpec,
    submitted: Instant,
}

/// Shared coordinator state.
struct Inner {
    queue: Mutex<VecDeque<Queued>>,
    queue_cv: Condvar,
    states: Mutex<HashMap<u64, JobState>>,
    /// Signalled (under the `states` lock) whenever a job reaches a
    /// terminal state, so `wait` latency is bounded by scheduling, not a
    /// poll interval.
    state_cv: Condvar,
    results: Mutex<HashMap<u64, JobResult>>,
    next_id: Mutex<u64>,
    shutdown: Mutex<bool>,
    /// Jobs handed to the pool but not yet complete (overlapping mode);
    /// `shutdown` drains this to zero before the dispatcher exits.
    inflight: Mutex<usize>,
    inflight_cv: Condvar,
    /// Replica work items currently in the pool; the dispatcher parks
    /// on `replica_cv` while `max_inflight_replicas` would be exceeded.
    inflight_replicas: Mutex<usize>,
    replica_cv: Condvar,
    /// Admission weight of every non-terminal job (queued or running)
    /// — what `try_submit` tests against the cap. A job's weight is
    /// `replicas × effective shard lanes`, so a sharded replica counts
    /// for every thread it will actually occupy, not just one.
    committed_replicas: Mutex<usize>,
    /// Copied from the config so the submit path can see the policy.
    admission_cap: usize,
    reject_when_saturated: bool,
    /// Resolved pool width (`cfg.workers`, with 0 resolved to the
    /// machine) — the budget auto-sharding plans against, needed at
    /// submit time to weight jobs consistently with execution.
    worker_budget: usize,
    /// Per-job control blocks (stop token, checkpoint journal, retry
    /// and deadline policy) for every NON-terminal job; entries are
    /// removed when the job's terminal state is published.
    ctls: Mutex<HashMap<u64, JobCtl>>,
    /// The deadline timer ("snowball-deadline" thread); also reused by
    /// the shutdown grace period.
    wheel: Arc<DeadlineWheel>,
    shutdown_grace_ms: u64,
    /// Content-addressed model store (`PUT` / `SOLVE model=`); shared
    /// with the router and sibling workers in a dispatch tier, private
    /// otherwise.
    registry: Arc<Registry>,
    /// id → model hash for registry-backed jobs. Each entry owns one
    /// registry pin (taken at [`Registry::checkout`] and handed over on
    /// a successful submit); the pin is released when the job's
    /// terminal state publishes, so a model stays eviction-proof
    /// exactly as long as work references it.
    pins: Mutex<HashMap<u64, ModelHash>>,
}

/// The job coordinator. Cloneable handle; `Drop` of the last handle does
/// not stop the dispatcher — call [`Coordinator::shutdown`].
#[derive(Clone)]
pub struct Coordinator {
    inner: Arc<Inner>,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start a coordinator with `workers` compute threads (0 = auto),
    /// overlapping dispatch, and a background dispatcher thread.
    pub fn start(workers: usize) -> Self {
        Self::start_with(CoordinatorConfig { workers, ..Default::default() })
    }

    /// Start a coordinator with the serial (one-job-at-a-time) dispatcher
    /// — the reference baseline the load harness compares against.
    pub fn start_serial(workers: usize) -> Self {
        Self::start_with(CoordinatorConfig {
            workers,
            mode: DispatchMode::Serial,
            ..Default::default()
        })
    }

    /// Start a coordinator with an explicit [`CoordinatorConfig`].
    pub fn start_with(cfg: CoordinatorConfig) -> Self {
        let metrics = Arc::new(Metrics::new());
        // A private registry publishes its gauges into this
        // coordinator's metrics; a shared (router-provided) one keeps
        // whatever sink was attached first, so tier-wide registry stats
        // land in exactly one METRICS output.
        let registry = match cfg.registry.clone() {
            Some(shared) => shared,
            None => {
                let own = Arc::new(Registry::with_defaults());
                own.attach_metrics(metrics.clone());
                own
            }
        };
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            states: Mutex::new(HashMap::new()),
            state_cv: Condvar::new(),
            results: Mutex::new(HashMap::new()),
            next_id: Mutex::new(1),
            shutdown: Mutex::new(false),
            inflight: Mutex::new(0),
            inflight_cv: Condvar::new(),
            inflight_replicas: Mutex::new(0),
            replica_cv: Condvar::new(),
            committed_replicas: Mutex::new(0),
            admission_cap: cfg.max_inflight_replicas,
            reject_when_saturated: cfg.reject_when_saturated,
            worker_budget: if cfg.workers == 0 {
                crate::engine::ReplicaPool::auto_workers()
            } else {
                cfg.workers
            },
            ctls: Mutex::new(HashMap::new()),
            wheel: Arc::new(DeadlineWheel::new()),
            shutdown_grace_ms: cfg.shutdown_grace_ms,
            registry,
            pins: Mutex::new(HashMap::new()),
        });
        let c = Self { inner: inner.clone(), metrics: metrics.clone() };
        let wheel = inner.wheel.clone();
        std::thread::Builder::new()
            .name("snowball-deadline".into())
            .spawn(move || wheel.run())
            .expect("spawn deadline wheel");
        let dispatcher = c.clone();
        std::thread::Builder::new()
            .name("snowball-dispatch".into())
            .spawn(move || dispatcher.dispatch_loop(cfg))
            .expect("spawn dispatcher");
        c
    }

    /// Submit a job; returns its id immediately. The job queues until
    /// the dispatcher picks it up (time spent there is the `queue_wait`
    /// histogram).
    ///
    /// ```
    /// use snowball::coordinator::{Backend, Coordinator, JobSpec};
    /// use snowball::engine::{Mode, Schedule, SelectorKind};
    /// use snowball::graph::generators;
    /// use snowball::problems::MaxCut;
    /// use snowball::rng::StatelessRng;
    /// use std::sync::Arc;
    ///
    /// let coord = Coordinator::start(2);
    /// let rng = StatelessRng::new(1);
    /// let problem = MaxCut::new(generators::erdos_renyi(16, 40, &[-1, 1], &rng));
    /// let id = coord.submit(JobSpec {
    ///     model: Arc::new(problem.model().clone()),
    ///     label: "doc".into(),
    ///     mode: Mode::RouletteWheel,
    ///     selector: SelectorKind::Fenwick,
    ///     schedule: Schedule::Geometric { t0: 4.0, t1: 0.1 },
    ///     steps: 200,
    ///     replicas: 2,
    ///     seed: 7,
    ///     target_energy: None,
    ///     shards: 1,
    ///     pin_lanes: false,
    ///     local_rows: false,
    ///     budget_ms: 0,
    ///     max_retries: 0,
    ///     backend: Backend::Native,
    ///     portfolio: None,
    /// });
    /// let result = coord.wait(id).expect("job completes");
    /// assert_eq!(result.replicas.len(), 2);
    /// coord.shutdown();
    /// ```
    pub fn submit(&self, spec: JobSpec) -> u64 {
        self.try_submit_inner(spec, false, None, None)
            .expect("unenforced submit cannot be rejected")
    }

    /// [`Self::submit`] with admission control: refuses the job when
    /// the coordinator was configured with a `max_inflight_replicas`
    /// cap plus `reject_when_saturated` and the committed (queued +
    /// running) replica count already meets the cap. This is the
    /// service's `SOLVE` path — rejected jobs become `ERR saturated …`
    /// on the wire and never enter the queue.
    pub fn try_submit(&self, spec: JobSpec) -> Result<u64, AdmissionError> {
        self.try_submit_inner(spec, true, None, None)
    }

    /// Submit on behalf of the dispatch-tier router. The job reuses the
    /// caller's checkpoint `journal` — so a job re-dispatched after a
    /// worker death resumes from its last [`journal::EngineCheckpoint`]
    /// instead of step 0 — and journals checkpoints even with
    /// `max_retries == 0`. When `hash` is `Some`, a successful submit
    /// takes ownership of one registry pin for the job's lifetime; on
    /// `Err` the pin stays with the caller (who must unpin).
    pub fn submit_managed(
        &self,
        spec: JobSpec,
        journal: Arc<JobJournal>,
        hash: Option<ModelHash>,
        enforce: bool,
    ) -> Result<u64, AdmissionError> {
        self.try_submit_inner(spec, enforce, Some(journal), hash)
    }

    /// A job's admission weight: `replicas × effective shard lanes` —
    /// the thread count the job will actually occupy, so sharded jobs
    /// cannot slip a multiplied load past a replica-counted cap. A
    /// portfolio job weighs the sum of its roster's lane counts (the
    /// contenders run concurrently).
    fn admission_weight(&self, spec: &JobSpec) -> usize {
        if let Some(p) = &spec.portfolio {
            return crate::portfolio::roster_weight(p, &spec.model);
        }
        spec.replicas as usize * scheduler::effective_shards(spec, self.inner.worker_budget).max(1)
    }

    fn try_submit_inner(
        &self,
        mut spec: JobSpec,
        enforce: bool,
        journal: Option<Arc<JobJournal>>,
        hash: Option<ModelHash>,
    ) -> Result<u64, AdmissionError> {
        if spec.portfolio.is_some() {
            // A race is one unit of dispatch however many contenders it
            // runs: replica fan-out, lane-weight accounting and the
            // result fold all key off `replicas == 1`.
            spec.replicas = 1;
        }
        let weight = self.admission_weight(&spec);
        {
            let mut committed = self.inner.committed_replicas.lock().unwrap();
            if enforce
                && self.inner.reject_when_saturated
                && self.inner.admission_cap > 0
                && *committed > 0
                && *committed + weight > self.inner.admission_cap
            {
                self.metrics.inc("jobs_rejected");
                return Err(AdmissionError::Saturated {
                    committed: *committed,
                    cap: self.inner.admission_cap,
                });
            }
            // Commit under the same lock so concurrent submits cannot
            // both squeeze past the cap.
            *committed += weight;
        }
        let id = {
            let mut next = self.inner.next_id.lock().unwrap();
            let id = *next;
            *next += 1;
            id
        };
        // The job's control block: cancel, the deadline wheel and
        // shutdown all trip the same token; the journal feeds
        // checkpointed retries (docs/ARCHITECTURE.md § Job lifecycle).
        // A router-provided journal additionally forces checkpointing
        // so a re-dispatch after worker death resumes mid-run.
        let managed = journal.is_some();
        let ctl = JobCtl {
            stop: Arc::new(StopToken::new()),
            journal: journal.unwrap_or_else(|| Arc::new(JobJournal::new())),
            max_retries: spec.max_retries,
            checkpoint: managed,
            deadline: (spec.budget_ms > 0)
                .then(|| Instant::now() + Duration::from_millis(spec.budget_ms)),
        };
        if let Some(when) = ctl.deadline {
            self.inner.wheel.schedule(when, StopCause::Deadline, ctl.stop.clone());
        }
        if let Some(h) = hash {
            // The caller's checkout pin now belongs to this job; it is
            // released when the terminal state publishes.
            self.inner.pins.lock().unwrap().insert(id, h);
        }
        self.inner.ctls.lock().unwrap().insert(id, ctl);
        self.inner.states.lock().unwrap().insert(id, JobState::Queued);
        self.inner
            .queue
            .lock()
            .unwrap()
            .push_back(Queued { id, spec, submitted: Instant::now() });
        self.inner.queue_cv.notify_one();
        self.metrics.inc("jobs_submitted");
        self.metrics.gauge_add("jobs_queued", 1);
        Ok(id)
    }

    /// Request cancellation of a queued or running job. Returns `true`
    /// if the request was delivered (the job's stop token tripped —
    /// though a racing deadline/shutdown may still label the outcome),
    /// `false` for unknown or already-terminal jobs. Cancellation is
    /// cooperative and asynchronous: the job reaches
    /// [`JobState::Cancelled`] with a partial [`JobResult`] once its
    /// replicas observe the token (engine stop stride / shard epoch
    /// boundary) — use [`Self::wait`] to rendezvous.
    pub fn cancel(&self, id: u64) -> bool {
        match self.inner.states.lock().unwrap().get(&id) {
            None => return false,
            Some(s) if s.is_terminal() => return false,
            Some(_) => {}
        }
        // The ctl may vanish between the check and here (job went
        // terminal) — that is the same benign race as a late deadline.
        match self.inner.ctls.lock().unwrap().get(&id) {
            Some(ctl) => {
                ctl.stop.trip(StopCause::Cancel);
                true
            }
            None => false,
        }
    }

    /// Current state of a job (None = unknown id).
    pub fn state(&self, id: u64) -> Option<JobState> {
        self.inner.states.lock().unwrap().get(&id).cloned()
    }

    /// Result of a finished job.
    pub fn result(&self, id: u64) -> Option<JobResult> {
        self.inner.results.lock().unwrap().get(&id).cloned()
    }

    /// Block until the job is terminal; returns its result — including
    /// the partial result of a cancelled / timed-out job — or `None`
    /// for an unknown id or a failed job. Condvar-notified on every
    /// terminal transition — no poll loop, so wait latency is not
    /// quantized to a sleep interval.
    ///
    /// ```
    /// use snowball::coordinator::Coordinator;
    ///
    /// let coord = Coordinator::start(1);
    /// assert!(coord.wait(999).is_none()); // unknown id: immediate None
    /// coord.shutdown();
    /// ```
    pub fn wait(&self, id: u64) -> Option<JobResult> {
        let mut states = self.inner.states.lock().unwrap();
        loop {
            match states.get(&id) {
                None => return None,
                Some(JobState::Failed(_)) => return None,
                Some(s) if s.is_terminal() => {
                    drop(states);
                    return self.result(id);
                }
                Some(_) => states = self.inner.state_cv.wait(states).unwrap(),
            }
        }
    }

    /// Bounded [`Self::wait`]: block until the job is terminal or
    /// `timeout` elapses. Unlike `wait`, the outcome distinguishes "no
    /// such job" from "still running" — the service's disconnect-aware
    /// `WAIT` loop needs both.
    pub fn wait_for(&self, id: u64, timeout: Duration) -> WaitOutcome {
        let deadline = Instant::now() + timeout;
        let mut states = self.inner.states.lock().unwrap();
        loop {
            match states.get(&id) {
                None => return WaitOutcome::Unknown,
                Some(s) if s.is_terminal() => return WaitOutcome::Terminal(s.clone()),
                Some(_) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return WaitOutcome::Pending;
                    }
                    let (guard, _) =
                        self.inner.state_cv.wait_timeout(states, deadline - now).unwrap();
                    states = guard;
                }
            }
        }
    }

    /// Stop the dispatcher. Queued and in-flight jobs still reach a
    /// terminal state before the dispatcher exits; with a nonzero
    /// [`CoordinatorConfig::shutdown_grace_ms`] any job still running
    /// when the grace period ends is preempted ([`StopCause::Shutdown`]
    /// → [`JobState::Cancelled`], partial result published), so
    /// shutdown completes promptly even under a multi-hour job. The
    /// default grace of `0` keeps the legacy drain-to-completion.
    pub fn shutdown(&self) {
        if self.inner.shutdown_grace_ms > 0 {
            let when = Instant::now() + Duration::from_millis(self.inner.shutdown_grace_ms);
            for ctl in self.inner.ctls.lock().unwrap().values() {
                self.inner.wheel.schedule(when, StopCause::Shutdown, ctl.stop.clone());
            }
        }
        *self.inner.shutdown.lock().unwrap() = true;
        self.inner.queue_cv.notify_all();
    }

    /// Publish a finished job: result map, terminal state (`Done`, or
    /// `Cancelled`/`TimedOut` when the job's stop token tripped —
    /// first cause wins), stage timers and lifecycle counters. Runs on
    /// the dispatcher thread (serial mode) or on the pool thread that
    /// completed the job's last replica (overlapping mode).
    fn complete(
        &self,
        id: u64,
        spec: &JobSpec,
        weight: usize,
        replicas: Vec<ReplicaResult>,
        submitted: Instant,
        run_start: Instant,
        ctl: &JobCtl,
    ) {
        let cause = ctl.stop.get();
        // Portfolio jobs fold their race outcome in here: contender i
        // reported as replica i, so the winner is the energy argmin
        // (roster order breaks ties — same rule as the race itself).
        let portfolio = spec.portfolio.as_ref().filter(|_| !replicas.is_empty()).map(|p| {
            let contenders = crate::portfolio::roster_names(p, &spec.model);
            let winner = replicas
                .iter()
                .min_by_key(|r| (r.best_energy, r.replica))
                .and_then(|r| contenders.get(r.replica as usize).cloned())
                .unwrap_or_default();
            PortfolioOutcome { winner, contenders }
        });
        if let Some(out) = &portfolio {
            self.metrics.inc("portfolio_races");
            self.metrics.add("portfolio_contenders", out.contenders.len() as u64);
            self.metrics
                .add("portfolio_losers_stopped", replicas.iter().filter(|r| r.stopped).count() as u64);
            self.metrics.inc(&format!("portfolio_wins_{}", out.winner));
        }
        if spec.pin_lanes {
            let pinned: usize = replicas.iter().map(|r| r.pinned_lanes).sum();
            self.metrics.gauge_set("pinned_lanes", pinned as i64);
        }
        if spec.local_rows {
            let local: usize = replicas.iter().map(|r| r.local_row_bytes).sum();
            self.metrics.gauge_set("local_row_bytes", local as i64);
        }
        let result = JobResult {
            job_id: id,
            label: spec.label.clone(),
            replicas,
            wall: run_start.elapsed(),
            completed: cause.is_none(),
            portfolio,
        };
        self.metrics.observe("run", result.wall);
        self.metrics.observe("job_wall", submitted.elapsed());
        self.metrics.gauge_add("jobs_running", -1);
        let retries = ctl.journal.retries();
        if retries > 0 {
            self.metrics.add("jobs_retried", retries);
        }
        let state = match cause {
            None => {
                self.metrics.inc("jobs_done");
                JobState::Done
            }
            Some(StopCause::Cancel) | Some(StopCause::Shutdown) => {
                self.metrics.inc("jobs_cancelled");
                JobState::Cancelled
            }
            Some(StopCause::Deadline) => {
                self.metrics.inc("jobs_timed_out");
                if let Some(dl) = ctl.deadline {
                    // How far past its budget the preempted job landed
                    // — the cooperative-preemption latency (stop
                    // stride / epoch barrier + teardown).
                    self.metrics
                        .observe("deadline_slack_us", Instant::now().saturating_duration_since(dl));
                }
                JobState::TimedOut
            }
        };
        self.inner.results.lock().unwrap().insert(id, result);
        self.inner.ctls.lock().unwrap().remove(&id);
        self.release_pin(id);
        // Release the admission budget BEFORE waking waiters: a client
        // unblocked by `wait` must be able to submit its next job
        // without racing the bookkeeping.
        self.release_committed(weight);
        self.inner.states.lock().unwrap().insert(id, state);
        self.inner.state_cv.notify_all();
    }

    /// Publish a failed job: terminal `Failed` state (message
    /// preserved for `STATUS`/`RESULT`), waiters woken, committed
    /// replicas released — the job's waiters see `None`, nothing
    /// wedges. Runs wherever [`Self::complete`] would have.
    fn fail(&self, id: u64, weight: usize, message: String, ctl: &JobCtl) {
        self.metrics.inc("jobs_failed");
        self.metrics.gauge_add("jobs_running", -1);
        let retries = ctl.journal.retries();
        if retries > 0 {
            self.metrics.add("jobs_retried", retries);
        }
        self.inner.ctls.lock().unwrap().remove(&id);
        self.release_pin(id);
        // Budget back before the wake-up, as in `complete`.
        self.release_committed(weight);
        self.inner.states.lock().unwrap().insert(id, JobState::Failed(message));
        self.inner.state_cv.notify_all();
    }

    /// A terminal job gives its weight back to the admission budget.
    fn release_committed(&self, weight: usize) {
        let mut committed = self.inner.committed_replicas.lock().unwrap();
        *committed = committed.saturating_sub(weight);
    }

    /// A terminal registry-backed job releases its model pin, making
    /// the model evictable again once no other job references it.
    fn release_pin(&self, id: u64) {
        let pinned = self.inner.pins.lock().unwrap().remove(&id);
        if let Some(h) = pinned {
            self.inner.registry.unpin(h);
        }
    }

    /// The content-addressed model registry backing `PUT` /
    /// `SOLVE model=` — shared across the tier when this coordinator is
    /// a router worker, private otherwise.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.inner.registry
    }

    /// Replica units currently committed (queued + running) against the
    /// admission cap. Exposed so the chaos suite can assert the budget
    /// is conserved — it must drain to 0 once every job is terminal,
    /// whatever mix of completions, failures, cancels and timeouts got
    /// them there.
    pub fn committed_weight(&self) -> usize {
        *self.inner.committed_replicas.lock().unwrap()
    }

    fn dispatch_loop(&self, cfg: CoordinatorConfig) {
        let scheduler = Arc::new(ReplicaScheduler::new(cfg.workers));
        loop {
            // Drain every queued job in one go: the batch is what the
            // size-class planner groups.
            let mut batch: Vec<Option<Queued>> = {
                let mut q = self.inner.queue.lock().unwrap();
                loop {
                    if !q.is_empty() {
                        break q.drain(..).map(Some).collect();
                    }
                    if *self.inner.shutdown.lock().unwrap() {
                        drop(q);
                        // Let in-flight overlapping jobs finish before the
                        // scheduler (and its pool) is torn down. With a
                        // grace period configured, `shutdown` already
                        // armed Shutdown trips on every live job, so
                        // this drain is bounded by the grace + one
                        // preemption latency rather than job length.
                        let mut inflight = self.inner.inflight.lock().unwrap();
                        while *inflight > 0 {
                            inflight = self.inner.inflight_cv.wait(inflight).unwrap();
                        }
                        drop(inflight);
                        self.inner.wheel.close();
                        return;
                    }
                    let (guard, _) = self
                        .inner
                        .queue_cv
                        .wait_timeout(q, std::time::Duration::from_millis(50))
                        .unwrap();
                    q = guard;
                }
            };
            // Dispatch order: serial keeps strict FIFO (it is the
            // reference baseline); overlapping walks the batcher's size
            // groups in ascending class order so each class's jobs enter
            // the pool together, then takes the overflow.
            let order: Vec<usize> = match cfg.mode {
                DispatchMode::Serial => (0..batch.len()).collect(),
                DispatchMode::Overlapping => {
                    let sizes: Vec<usize> =
                        batch.iter().map(|b| b.as_ref().unwrap().spec.model.len()).collect();
                    let plan = batcher::plan(&sizes, &cfg.classes);
                    let groups = plan.groups();
                    self.metrics.add("batch_groups", groups.len() as u64);
                    self.metrics.add("batch_overflow_jobs", plan.overflow.len() as u64);
                    groups
                        .into_iter()
                        .flat_map(|(_, jobs)| jobs)
                        .chain(plan.overflow.iter().copied())
                        .collect()
                }
            };
            for idx in order {
                let Queued { id, spec, submitted } = batch[idx].take().expect("each job once");
                let picked_up = Instant::now();
                self.metrics.observe("queue_wait", submitted.elapsed());
                self.metrics.gauge_add("jobs_queued", -1);
                // The control block was created at submit; a missing
                // entry (impossible today) degrades to an unmanaged
                // run rather than a panic on the dispatcher thread.
                let ctl = self
                    .inner
                    .ctls
                    .lock()
                    .unwrap()
                    .get(&id)
                    .cloned()
                    .unwrap_or_else(JobCtl::unmanaged);
                let replica_count = spec.replicas;
                // Admission weight = replicas × shard lanes: the thread
                // count the job will actually occupy.
                let weight = self.admission_weight(&spec);
                // Preempted while still queued (cancel before dispatch,
                // a deadline shorter than the queue wait, shutdown
                // grace): finalize right here with an empty partial
                // result — no pool time is spent on a dead job.
                if ctl.stop.is_stopped() {
                    self.metrics.gauge_add("jobs_running", 1);
                    self.metrics.observe("dispatch", picked_up.elapsed());
                    self.complete(id, &spec, weight, Vec::new(), submitted, picked_up, &ctl);
                    continue;
                }
                self.inner.states.lock().unwrap().insert(id, JobState::Running);
                self.metrics.gauge_add("jobs_running", 1);
                // The XLA backend is driven synchronously by callers that
                // own a runtime (examples/k2000_tts.rs); queued jobs fall
                // back to native execution so the service never needs a
                // PJRT client it might not have.
                match cfg.mode {
                    DispatchMode::Serial => {
                        self.metrics.observe("dispatch", picked_up.elapsed());
                        let run_start = Instant::now();
                        match scheduler.try_run_native_ctl(&spec, &ctl) {
                            Ok(replicas) => self.complete(
                                id, &spec, weight, replicas, submitted, run_start, &ctl,
                            ),
                            Err(msg) => self.fail(id, weight, msg, &ctl),
                        }
                    }
                    DispatchMode::Overlapping => {
                        // Admission backpressure: park until this job's
                        // weight fits under the inflight cap (a job
                        // heavier than the whole cap still runs —
                        // alone). Parked time is charged to the
                        // `dispatch` timer, so saturation is visible in
                        // METRICS.
                        if cfg.max_inflight_replicas > 0 {
                            let mut inflight = self.inner.inflight_replicas.lock().unwrap();
                            while *inflight > 0
                                && *inflight + weight > cfg.max_inflight_replicas
                            {
                                inflight = self.inner.replica_cv.wait(inflight).unwrap();
                            }
                            *inflight += weight;
                        } else {
                            *self.inner.inflight_replicas.lock().unwrap() += weight;
                        }
                        *self.inner.inflight.lock().unwrap() += 1;
                        self.metrics.gauge_add("replicas_inflight", replica_count as i64);
                        // Each finished replica releases its share of
                        // the job's weight (lanes per replica).
                        let lane_weight = match replica_count {
                            0 => 0,
                            r => weight / r as usize,
                        };
                        let spec = Arc::new(spec);
                        let done_spec = spec.clone();
                        let this = self.clone();
                        let per_replica = self.clone();
                        // Observe before handing off: a tiny job may
                        // complete (and wake waiters) the moment it is
                        // spawned, and by then its dispatch sample must
                        // already be visible.
                        self.metrics.observe("dispatch", picked_up.elapsed());
                        let run_start = Instant::now();
                        let job_ctl = ctl.clone();
                        scheduler.spawn_native(
                            spec,
                            ctl,
                            move || {
                                per_replica.metrics.gauge_add("replicas_inflight", -1);
                                let mut inflight =
                                    per_replica.inner.inflight_replicas.lock().unwrap();
                                *inflight -= lane_weight;
                                per_replica.inner.replica_cv.notify_all();
                            },
                            move |outcome| {
                                match outcome {
                                    Ok(replicas) => this.complete(
                                        id,
                                        &done_spec,
                                        weight,
                                        replicas,
                                        submitted,
                                        run_start,
                                        &job_ctl,
                                    ),
                                    Err(msg) => this.fail(id, weight, msg, &job_ctl),
                                }
                                let mut inflight = this.inner.inflight.lock().unwrap();
                                *inflight -= 1;
                                this.inner.inflight_cv.notify_all();
                            },
                        );
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Mode, Schedule, SelectorKind};
    use crate::graph::generators;
    use crate::problems::MaxCut;
    use crate::rng::StatelessRng;

    fn spec(label: &str, seed: u64) -> JobSpec {
        let rng = StatelessRng::new(seed);
        let p = MaxCut::new(generators::erdos_renyi(32, 120, &[-1, 1], &rng));
        JobSpec {
            model: Arc::new(p.model().clone()),
            label: label.into(),
            mode: Mode::RouletteWheel,
            selector: SelectorKind::Fenwick,
            schedule: Schedule::Geometric { t0: 5.0, t1: 0.05 },
            steps: 400,
            replicas: 4,
            seed,
            target_energy: None,
            shards: 1,
            pin_lanes: false,
            local_rows: false,
            budget_ms: 0,
            max_retries: 0,
            backend: Backend::Native,
            portfolio: None,
        }
    }

    #[test]
    fn submit_wait_result_lifecycle() {
        let c = Coordinator::start(2);
        let id = c.submit(spec("a", 1));
        let r = c.wait(id).expect("job should finish");
        assert_eq!(r.job_id, id);
        assert_eq!(r.replicas.len(), 4);
        assert_eq!(c.state(id), Some(JobState::Done));
        assert_eq!(c.metrics.get("jobs_done"), 1);
        c.shutdown();
    }

    #[test]
    fn multiple_jobs_fifo_and_isolated() {
        let c = Coordinator::start(2);
        let id1 = c.submit(spec("one", 1));
        let id2 = c.submit(spec("two", 2));
        let r1 = c.wait(id1).unwrap();
        let r2 = c.wait(id2).unwrap();
        assert_eq!(r1.label, "one");
        assert_eq!(r2.label, "two");
        assert_ne!(
            r1.replicas.iter().map(|r| r.best_energy).collect::<Vec<_>>(),
            r2.replicas.iter().map(|r| r.best_energy).collect::<Vec<_>>(),
        );
        c.shutdown();
    }

    /// Several threads blocked in `wait` on the same job must all be
    /// woken by the terminal-state notification (no poll loop involved).
    #[test]
    fn concurrent_waiters_all_notified() {
        let c = Coordinator::start(2);
        let id = c.submit(spec("shared", 7));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || c.wait(id).map(|r| r.job_id))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), Some(id));
        }
        c.shutdown();
    }

    #[test]
    fn unknown_job_is_none() {
        let c = Coordinator::start(1);
        assert!(c.state(999).is_none());
        assert!(c.result(999).is_none());
        assert!(c.wait(999).is_none());
        c.shutdown();
    }

    /// Serial and overlapping dispatch must produce identical per-job
    /// results (same replicas, energies, flips) for the same specs.
    #[test]
    fn overlapping_matches_serial_dispatch_results() {
        let key = |r: &JobResult| -> Vec<(u32, i64, u64)> {
            r.replicas.iter().map(|p| (p.replica, p.best_energy, p.flips)).collect()
        };
        let run = |c: Coordinator| -> Vec<Vec<(u32, i64, u64)>> {
            let ids: Vec<u64> = (0..5).map(|k| c.submit(spec(&format!("j{k}"), 50 + k))).collect();
            let out = ids.iter().map(|&id| key(&c.wait(id).unwrap())).collect();
            c.shutdown();
            out
        };
        let serial = run(Coordinator::start_serial(3));
        let overlapping = run(Coordinator::start(3));
        assert_eq!(serial, overlapping, "dispatch mode must not change results");
    }

    /// The per-stage timers and occupancy gauges must be live after a
    /// batch of jobs drains, and occupancy must return to zero.
    #[test]
    fn stage_timers_and_gauges_are_published() {
        let c = Coordinator::start(2);
        let ids: Vec<u64> = (0..4).map(|k| c.submit(spec(&format!("m{k}"), 80 + k))).collect();
        for id in ids {
            c.wait(id).unwrap();
        }
        for series in ["queue_wait", "dispatch", "run", "job_wall"] {
            assert_eq!(c.metrics.samples(series), 4, "{series} should have one sample per job");
            assert!(c.metrics.quantile_us(series, 0.99).is_some());
        }
        assert_eq!(c.metrics.get("jobs_done"), 4);
        assert_eq!(c.metrics.gauge("jobs_queued"), 0);
        assert_eq!(c.metrics.gauge("jobs_running"), 0);
        assert_eq!(c.metrics.gauge("replicas_inflight"), 0);
        c.shutdown();
    }

    /// `shutdown` must drain queued + in-flight jobs before the
    /// dispatcher (and its pool) goes away: anything submitted before
    /// the call still completes.
    #[test]
    fn shutdown_drains_inflight_jobs() {
        let c = Coordinator::start(2);
        let ids: Vec<u64> = (0..6).map(|k| c.submit(spec(&format!("d{k}"), 200 + k))).collect();
        c.shutdown();
        for id in ids {
            assert!(c.wait(id).is_some(), "job {id} must survive shutdown draining");
        }
    }

    /// A job whose replicas panic (poisoned zero-spin instance) must
    /// reach `JobState::Failed`, wake its waiters with `None`, and
    /// leave the dispatcher healthy for the next job — under both
    /// dispatch modes.
    #[test]
    fn failed_job_wakes_waiters_and_dispatcher_survives() {
        for c in [Coordinator::start(2), Coordinator::start_serial(2)] {
            let mut bad = spec("poisoned", 5);
            bad.model = Arc::new(crate::ising::IsingModel::zeros(0));
            let bad_id = c.submit(bad);
            assert!(c.wait(bad_id).is_none(), "failed job must yield None");
            match c.state(bad_id) {
                Some(JobState::Failed(msg)) => {
                    assert!(msg.contains("panicked"), "unexpected failure message: {msg}")
                }
                other => panic!("expected Failed, got {other:?}"),
            }
            assert_eq!(c.metrics.get("jobs_failed"), 1);
            // The machine is still alive: a healthy job completes.
            let ok_id = c.submit(spec("after", 6));
            assert!(c.wait(ok_id).is_some(), "dispatcher must survive a failed job");
            assert_eq!(c.metrics.gauge("jobs_running"), 0);
            c.shutdown();
        }
    }

    /// With `max_inflight_replicas` set, the overlapping dispatcher
    /// parks instead of flooding the pool: the `replicas_inflight`
    /// gauge never exceeds the cap, yet every job completes.
    #[test]
    fn inflight_replica_cap_parks_but_everything_completes() {
        let cap = 4usize;
        let c = Coordinator::start_with(CoordinatorConfig {
            workers: 2,
            max_inflight_replicas: cap,
            ..Default::default()
        });
        let done = Arc::new(crate::sync::atomic::AtomicBool::new(false));
        let poller = {
            let (c, done) = (c.clone(), done.clone());
            std::thread::spawn(move || {
                let mut peak = 0i64;
                while !done.load(crate::sync::atomic::Ordering::Relaxed) {
                    peak = peak.max(c.metrics.gauge("replicas_inflight"));
                    std::thread::yield_now();
                }
                peak
            })
        };
        let ids: Vec<u64> = (0..6).map(|k| c.submit(spec(&format!("cap{k}"), 400 + k))).collect();
        for id in ids {
            assert!(c.wait(id).is_some(), "job {id} must complete under the cap");
        }
        done.store(true, crate::sync::atomic::Ordering::Relaxed);
        let peak = poller.join().unwrap();
        assert!(peak <= cap as i64, "inflight replicas peaked at {peak}, cap {cap}");
        assert_eq!(c.metrics.gauge("replicas_inflight"), 0);
        c.shutdown();
    }

    /// With rejection enabled, `try_submit` refuses jobs while the
    /// committed replica budget is exhausted and admits again once the
    /// saturating job drains.
    #[test]
    fn try_submit_rejects_when_saturated_and_recovers() {
        let c = Coordinator::start_with(CoordinatorConfig {
            workers: 1,
            max_inflight_replicas: 4,
            reject_when_saturated: true,
            ..Default::default()
        });
        let mut long = spec("long", 9);
        long.steps = 100_000; // keeps the budget committed for a while
        let id = c.try_submit(long).expect("first job fits an idle coordinator");
        match c.try_submit(spec("burst", 10)) {
            Err(AdmissionError::Saturated { committed, cap }) => {
                assert_eq!((committed, cap), (4, 4));
            }
            other => panic!("expected saturation, got {other:?}"),
        }
        assert_eq!(c.metrics.get("jobs_rejected"), 1);
        assert!(c.wait(id).is_some());
        // Budget released: admission works again.
        let id2 = c.try_submit(spec("retry", 11)).expect("drained coordinator admits");
        assert!(c.wait(id2).is_some());
        c.shutdown();
    }

    /// A registry-backed job holds its model pin exactly for its
    /// lifetime: pinned from submit (the checkout pin is handed over),
    /// released — hence evictable — once the terminal state publishes.
    #[test]
    fn registry_pin_released_at_terminal_state() {
        let c = Coordinator::start(2);
        let h = c.registry().put((*spec("pin", 3).model).clone()).unwrap();
        let model = c.registry().checkout(h).expect("stored model");
        let mut managed = spec("pin", 3);
        managed.model = model;
        let id = c
            .submit_managed(managed, Arc::new(JobJournal::new()), Some(h), false)
            .expect("unenforced submit cannot be rejected");
        assert!(c.wait(id).is_some());
        assert_eq!(c.registry().stats().pinned, 0, "terminal job must unpin its model");
        assert!(c.registry().contains(h), "unpinned is not evicted while capacity lasts");
        c.shutdown();
    }

    /// `cancel` preempts a running job: `wait` returns a partial
    /// result (`completed == false`), the state is `Cancelled`, the
    /// lifecycle counters and occupancy gauges settle, and repeated /
    /// unknown cancels are refused.
    #[test]
    fn cancel_preempts_running_job_with_partial_result() {
        let c = Coordinator::start(2);
        let mut long = spec("cancel-me", 31);
        long.steps = 2_000_000_000; // minutes if not preempted
        long.replicas = 2;
        let id = c.submit(long);
        // Let the dispatcher hand it to the pool, then cancel.
        while c.state(id) == Some(JobState::Queued) {
            std::thread::yield_now();
        }
        assert!(c.cancel(id), "live job must accept the cancel");
        let t0 = Instant::now();
        let r = c.wait(id).expect("cancelled job still publishes a partial result");
        assert!(t0.elapsed() < Duration::from_secs(30), "preemption must be prompt");
        assert!(!r.completed);
        assert_eq!(r.replicas.len(), 2, "every replica reports its incumbent");
        assert_eq!(c.state(id), Some(JobState::Cancelled));
        assert_eq!(c.metrics.get("jobs_cancelled"), 1);
        assert_eq!(c.metrics.get("jobs_done"), 0);
        assert!(!c.cancel(id), "terminal job refuses further cancels");
        assert!(!c.cancel(9999), "unknown job refuses cancels");
        assert_eq!(c.metrics.gauge("jobs_running"), 0);
        assert_eq!(c.metrics.gauge("replicas_inflight"), 0);
        assert_eq!(c.committed_weight(), 0, "admission budget must be conserved");
        c.shutdown();
    }

    /// `budget_ms` flows from spec to deadline wheel to stop token:
    /// the job lands in `TimedOut` with a valid partial result well
    /// before its uninterrupted runtime, and the slack histogram gets
    /// its sample.
    #[test]
    fn budget_ms_deadline_times_out_with_partial_result() {
        let c = Coordinator::start(2);
        let mut long = spec("deadline", 32);
        long.steps = 2_000_000_000;
        long.replicas = 2;
        long.budget_ms = 50;
        let id = c.submit(long);
        let r = c.wait(id).expect("timed-out job still publishes a partial result");
        assert!(!r.completed);
        assert_eq!(c.state(id), Some(JobState::TimedOut));
        assert_eq!(c.metrics.get("jobs_timed_out"), 1);
        assert_eq!(c.metrics.samples("deadline_slack_us"), 1);
        assert_eq!(c.metrics.gauge("jobs_running"), 0);
        assert_eq!(c.committed_weight(), 0);
        c.shutdown();
    }

    /// A job cancelled while still queued is finalized by the
    /// dispatcher without touching the pool: empty replica vector,
    /// `Cancelled`, budget conserved.
    #[test]
    fn queued_job_cancelled_before_dispatch_finalizes_empty() {
        // Serial dispatcher + a long head job keep the victim queued.
        let c = Coordinator::start_serial(1);
        let mut head = spec("head", 33);
        head.steps = 50_000_000;
        head.replicas = 1;
        let head_id = c.submit(head);
        let mut victim = spec("victim", 34);
        victim.replicas = 3;
        let victim_id = c.submit(victim);
        assert!(c.cancel(victim_id), "queued job must accept the cancel");
        assert!(c.cancel(head_id)); // unblock the head quickly too
        let v = c.wait(victim_id).expect("queued-cancelled job publishes a result");
        assert!(!v.completed);
        assert!(v.replicas.is_empty(), "never dispatched → no replica results");
        assert_eq!(c.state(victim_id), Some(JobState::Cancelled));
        assert!(c.wait(head_id).is_some());
        assert_eq!(c.committed_weight(), 0);
        assert_eq!(c.metrics.gauge("jobs_running"), 0);
        c.shutdown();
    }

    /// Sharded jobs weigh `replicas × lanes` against the cap: a
    /// 2-replica × 3-lane job is 6 units and must be refused where a
    /// plain 2-replica job would fit.
    #[test]
    fn sharded_jobs_are_weighted_against_the_cap() {
        let c = Coordinator::start_with(CoordinatorConfig {
            workers: 1,
            max_inflight_replicas: 4,
            reject_when_saturated: true,
            ..Default::default()
        });
        let mut long = spec("w-long", 21);
        long.steps = 100_000;
        long.replicas = 1; // weight 1 — leaves 3 units of headroom
        let id = c.try_submit(long).expect("1 unit fits");
        let mut heavy = spec("w-heavy", 22);
        heavy.replicas = 2;
        heavy.shards = 3;
        assert!(c.try_submit(heavy).is_err(), "2 replicas x 3 lanes = 6 units must be refused");
        let plain = spec("w-plain", 23); // 4 replicas x 1 lane — still too heavy (1+4 > 4)
        assert!(c.try_submit(plain).is_err());
        assert!(c.wait(id).is_some());
        c.shutdown();
    }
}
