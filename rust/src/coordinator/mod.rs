//! L3 coordinator: job queue, replica scheduling, size batching, metrics
//! and the TCP service (DESIGN.md §2, L3 row).
//!
//! The coordinator owns the machine: callers submit [`job::JobSpec`]s;
//! a background dispatcher drains the queue, fans replicas over the
//! [`scheduler::ReplicaScheduler`] thread pool, and publishes
//! [`job::JobResult`]s. Requests never touch Python — the XLA backend
//! executes pre-compiled artifacts via `crate::runtime`.

pub mod batcher;
pub mod job;
pub mod metrics;
pub mod scheduler;
pub mod service;

pub use job::{Backend, JobResult, JobSpec, JobState, ReplicaResult};
pub use metrics::Metrics;
pub use scheduler::ReplicaScheduler;
pub use service::Service;

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

/// Shared coordinator state.
struct Inner {
    queue: Mutex<VecDeque<(u64, JobSpec)>>,
    queue_cv: Condvar,
    states: Mutex<HashMap<u64, JobState>>,
    /// Signalled (under the `states` lock) whenever a job reaches a
    /// terminal state, so `wait` latency is bounded by scheduling, not a
    /// poll interval.
    state_cv: Condvar,
    results: Mutex<HashMap<u64, JobResult>>,
    next_id: Mutex<u64>,
    shutdown: Mutex<bool>,
}

/// The job coordinator. Cloneable handle; `Drop` of the last handle does
/// not stop the dispatcher — call [`Coordinator::shutdown`].
#[derive(Clone)]
pub struct Coordinator {
    inner: Arc<Inner>,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Start a coordinator with `workers` compute threads (0 = auto) and
    /// a background dispatcher thread.
    pub fn start(workers: usize) -> Self {
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            states: Mutex::new(HashMap::new()),
            state_cv: Condvar::new(),
            results: Mutex::new(HashMap::new()),
            next_id: Mutex::new(1),
            shutdown: Mutex::new(false),
        });
        let metrics = Arc::new(Metrics::new());
        let c = Self { inner: inner.clone(), metrics: metrics.clone() };
        let dispatcher = c.clone();
        std::thread::Builder::new()
            .name("snowball-dispatch".into())
            .spawn(move || dispatcher.dispatch_loop(workers))
            .expect("spawn dispatcher");
        c
    }

    /// Submit a job; returns its id immediately.
    pub fn submit(&self, spec: JobSpec) -> u64 {
        let id = {
            let mut next = self.inner.next_id.lock().unwrap();
            let id = *next;
            *next += 1;
            id
        };
        self.inner.states.lock().unwrap().insert(id, JobState::Queued);
        self.inner.queue.lock().unwrap().push_back((id, spec));
        self.inner.queue_cv.notify_one();
        self.metrics.inc("jobs_submitted");
        id
    }

    /// Current state of a job (None = unknown id).
    pub fn state(&self, id: u64) -> Option<JobState> {
        self.inner.states.lock().unwrap().get(&id).cloned()
    }

    /// Result of a finished job.
    pub fn result(&self, id: u64) -> Option<JobResult> {
        self.inner.results.lock().unwrap().get(&id).cloned()
    }

    /// Block until the job finishes (or fails); returns its result.
    /// Condvar-notified by the dispatcher on every terminal transition —
    /// no poll loop, so wait latency is not quantized to a sleep
    /// interval.
    pub fn wait(&self, id: u64) -> Option<JobResult> {
        let mut states = self.inner.states.lock().unwrap();
        loop {
            match states.get(&id) {
                None => return None,
                Some(JobState::Done) => {
                    drop(states);
                    return self.result(id);
                }
                Some(JobState::Failed(_)) => return None,
                Some(_) => states = self.inner.state_cv.wait(states).unwrap(),
            }
        }
    }

    /// Stop the dispatcher after the current job.
    pub fn shutdown(&self) {
        *self.inner.shutdown.lock().unwrap() = true;
        self.inner.queue_cv.notify_all();
    }

    fn dispatch_loop(&self, workers: usize) {
        let pool = ReplicaScheduler::new(workers);
        loop {
            let item = {
                let mut q = self.inner.queue.lock().unwrap();
                loop {
                    if *self.inner.shutdown.lock().unwrap() {
                        return;
                    }
                    if let Some(item) = q.pop_front() {
                        break Some(item);
                    }
                    let (guard, _) =
                        self.inner.queue_cv.wait_timeout(q, std::time::Duration::from_millis(50)).unwrap();
                    q = guard;
                }
            };
            let Some((id, spec)) = item else { return };
            self.inner.states.lock().unwrap().insert(id, JobState::Running);
            let start = std::time::Instant::now();
            let replicas = match spec.backend {
                Backend::Native => pool.run_native(&spec),
                // The XLA backend is driven synchronously by callers that
                // own a runtime (examples/k2000_tts.rs); queued jobs fall
                // back to native execution so the service never needs a
                // PJRT client it might not have.
                Backend::Xla => pool.run_native(&spec),
            };
            let result = JobResult { job_id: id, label: spec.label.clone(), replicas, wall: start.elapsed() };
            self.metrics.observe("job_wall", result.wall);
            self.metrics.inc("jobs_done");
            self.inner.results.lock().unwrap().insert(id, result);
            self.inner.states.lock().unwrap().insert(id, JobState::Done);
            self.inner.state_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Mode, Schedule, SelectorKind};
    use crate::graph::generators;
    use crate::problems::MaxCut;
    use crate::rng::StatelessRng;

    fn spec(label: &str, seed: u64) -> JobSpec {
        let rng = StatelessRng::new(seed);
        let p = MaxCut::new(generators::erdos_renyi(32, 120, &[-1, 1], &rng));
        JobSpec {
            model: Arc::new(p.model().clone()),
            label: label.into(),
            mode: Mode::RouletteWheel,
            selector: SelectorKind::Fenwick,
            schedule: Schedule::Geometric { t0: 5.0, t1: 0.05 },
            steps: 400,
            replicas: 4,
            seed,
            target_energy: None,
            backend: Backend::Native,
        }
    }

    #[test]
    fn submit_wait_result_lifecycle() {
        let c = Coordinator::start(2);
        let id = c.submit(spec("a", 1));
        let r = c.wait(id).expect("job should finish");
        assert_eq!(r.job_id, id);
        assert_eq!(r.replicas.len(), 4);
        assert_eq!(c.state(id), Some(JobState::Done));
        assert_eq!(c.metrics.get("jobs_done"), 1);
        c.shutdown();
    }

    #[test]
    fn multiple_jobs_fifo_and_isolated() {
        let c = Coordinator::start(2);
        let id1 = c.submit(spec("one", 1));
        let id2 = c.submit(spec("two", 2));
        let r1 = c.wait(id1).unwrap();
        let r2 = c.wait(id2).unwrap();
        assert_eq!(r1.label, "one");
        assert_eq!(r2.label, "two");
        assert_ne!(
            r1.replicas.iter().map(|r| r.best_energy).collect::<Vec<_>>(),
            r2.replicas.iter().map(|r| r.best_energy).collect::<Vec<_>>(),
        );
        c.shutdown();
    }

    /// Several threads blocked in `wait` on the same job must all be
    /// woken by the terminal-state notification (no poll loop involved).
    #[test]
    fn concurrent_waiters_all_notified() {
        let c = Coordinator::start(2);
        let id = c.submit(spec("shared", 7));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || c.wait(id).map(|r| r.job_id))
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), Some(id));
        }
        c.shutdown();
    }

    #[test]
    fn unknown_job_is_none() {
        let c = Coordinator::start(1);
        assert!(c.state(999).is_none());
        assert!(c.result(999).is_none());
        assert!(c.wait(999).is_none());
        c.shutdown();
    }
}
