//! Problem encodings onto the Ising substrate (paper §II-A) and the
//! precision/landscape analyses of §III-C.

pub mod ancilla;
pub mod landscape;
pub mod maxcut;
pub mod partition;
pub mod quantize;
pub mod qubo;
pub mod tsp;

pub use maxcut::MaxCut;
pub use partition::GraphPartition;
pub use qubo::Qubo;
