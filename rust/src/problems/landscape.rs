//! Exhaustive energy-landscape enumeration for small instances
//! (Figs. 2 and 8) and exact ground-state search.

use crate::ising::{IsingModel, SpinVec};

/// Energies of all 2^n configurations, indexed by the bit pattern
/// `x_i = bit i` (x=1 ⇔ s=+1). Only feasible for n ≤ ~24.
pub fn enumerate(model: &IsingModel) -> Vec<i64> {
    let n = model.len();
    assert!(n <= 24, "landscape enumeration is exponential; n = {n} too large");
    let mut out = Vec::with_capacity(1usize << n);
    let mut s = SpinVec::all_down(n);
    // Gray-code walk with incremental energy would be faster, but the
    // direct form is the verification oracle — keep it obvious.
    for pattern in 0u32..(1u32 << n) {
        for i in 0..n {
            s.set(i, if (pattern >> i) & 1 == 1 { 1 } else { -1 });
        }
        out.push(model.energy(&s));
    }
    out
}

/// Exact ground state by exhaustive search: `(config bits, energy)`.
pub fn ground_state(model: &IsingModel) -> (u32, i64) {
    let e = enumerate(model);
    let (idx, &min) = e.iter().enumerate().min_by_key(|(_, &v)| v).unwrap();
    (idx as u32, min)
}

/// Decode an enumeration index into a spin configuration.
pub fn config_of_index(n: usize, pattern: u32) -> SpinVec {
    let mut s = SpinVec::all_down(n);
    for i in 0..n {
        if (pattern >> i) & 1 == 1 {
            s.set(i, 1);
        }
    }
    s
}

/// The fully connected five-spin example of Fig. 2. Couplings/fields are
/// chosen so the ground state is s = (+1,+1,−1,+1,−1) with
/// H = −14 − 10 = −24, as stated in the paper.
pub fn fig2_k5() -> IsingModel {
    let mut m = IsingModel::zeros(5);
    // Pair term must contribute −14 and field term −10 at the target
    // configuration s* = (+,+,−,+,−).
    // Pairs (i<j) and s_i s_j at s*: (0,1)=+1 (0,2)=−1 (0,3)=+1 (0,4)=−1
    // (1,2)=−1 (1,3)=+1 (1,4)=−1 (2,3)=−1 (2,4)=+1 (3,4)=−1
    m.set_j(0, 1, 3); //  +3
    m.set_j(0, 2, -2); //  +2
    m.set_j(0, 3, 1); //  +1
    m.set_j(0, 4, -1); //  +1
    m.set_j(1, 2, -2); //  +2
    m.set_j(1, 3, 2); //  +2
    m.set_j(1, 4, -1); //  +1
    m.set_j(2, 3, -1); //  +1
    m.set_j(2, 4, 1); //  +1
    m.set_j(3, 4, 0); //   0   (Σ J_ij s_i s_j = 14)
    // Fields: Σ h_i s_i = 10 at s*.
    m.set_h(0, 2); //  +2
    m.set_h(1, 3); //  +3
    m.set_h(2, -2); //  +2
    m.set_h(3, 2); //  +2
    m.set_h(4, -1); //  +1
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_ground_state_matches_paper() {
        let m = fig2_k5();
        let s_star = SpinVec::from_spins(&[1, 1, -1, 1, -1]);
        assert_eq!(m.energy(&s_star), -24, "paper states H(s*) = -24");
        let (idx, e) = ground_state(&m);
        assert_eq!(e, -24);
        assert_eq!(config_of_index(5, idx).to_spins(), s_star.to_spins());
    }

    #[test]
    fn enumeration_size_and_symmetry() {
        let m = fig2_k5();
        let e = enumerate(&m);
        assert_eq!(e.len(), 32);
        // With h ≠ 0 the landscape is NOT spin-flip symmetric; zero the
        // fields and it must be.
        let mut m0 = m.clone();
        for i in 0..5 {
            m0.set_h(i, 0);
        }
        let e0 = enumerate(&m0);
        for p in 0u32..32 {
            assert_eq!(e0[p as usize], e0[(!p & 31) as usize], "Z2 symmetry at {p}");
        }
    }

    #[test]
    fn quantized_landscape_differs() {
        // Fig 8: 2-bit arithmetic shift of the K5 instance changes the
        // landscape (and here, the ground state energy).
        let m = fig2_k5();
        let q = crate::problems::quantize::arithmetic_shift(&m, 2);
        assert_ne!(enumerate(&m), enumerate(&q));
    }
}
