//! QUBO ingest (qbsolv text format) and the MaxCut `.mc` alias —
//! real-world scenario variety for the solver portfolio.
//!
//! A QUBO minimizes `E(x) = Σ_i Q_ii·x_i + Σ_{i<j} (Q_ij+Q_ji)·x_i·x_j`
//! over binary `x`. Substituting `x_i = (1+s_i)/2` maps it onto the
//! paper's Ising Hamiltonian (Eq. 1, `H = −ΣJss − Σhs`): with
//! `q_ij = Q_ij + Q_ji` and `lin_i = Q_ii`,
//!
//! `4·E(x) = C + Σ_i a_i·s_i + Σ_{i<j} q_ij·s_i·s_j`
//!
//! where `a_i = 2·lin_i + Σ_{j≠i} q_ij` and
//! `C = 2·Σ_i lin_i + Σ_{i<j} q_ij`. Setting `J_ij = −q_ij` and
//! `h_i = −a_i` gives `E(x) = (H(s) + C) / 4` exactly (all-integer, and
//! `H + C` is always divisible by 4) — so minimizing the Ising model
//! minimizes the QUBO, and [`Qubo::energy`] recovers the original
//! objective for round-trip tests.

use crate::ising::{IsingModel, SpinVec};
use crate::problems::MaxCut;

/// A QUBO instance converted to Ising form.
pub struct Qubo {
    pub model: IsingModel,
    /// The constant `C` of the conversion: `E_qubo = (H + C) / 4`.
    pub offset: i64,
}

impl Qubo {
    /// Build from `(i, j, value)` entries. Diagonal entries (`i == j`)
    /// are the linear terms; off-diagonal duplicates and transposes
    /// accumulate (`q_ij = Q_ij + Q_ji`).
    pub fn from_entries(n: usize, entries: &[(usize, usize, i64)]) -> Result<Qubo, String> {
        let mut lin = vec![0i64; n];
        let mut quad = vec![0i64; n * n]; // upper triangle (i < j)
        for &(i, j, v) in entries {
            if i >= n || j >= n {
                return Err(format!("qubo entry ({i},{j}) out of range for n={n}"));
            }
            if i == j {
                lin[i] += v;
            } else {
                let (a, b) = if i < j { (i, j) } else { (j, i) };
                quad[a * n + b] += v;
            }
        }
        let mut model = IsingModel::zeros(n);
        let mut offset: i64 = lin.iter().map(|&l| 2 * l).sum();
        let mut a: Vec<i64> = lin.iter().map(|&l| 2 * l).collect();
        for i in 0..n {
            for j in (i + 1)..n {
                let q = quad[i * n + j];
                if q == 0 {
                    continue;
                }
                offset += q;
                a[i] += q;
                a[j] += q;
                let jv = i32::try_from(-q)
                    .map_err(|_| format!("qubo coupling ({i},{j}) overflows i32"))?;
                model.set_j(i, j, jv);
            }
        }
        for (i, &ai) in a.iter().enumerate() {
            let hv = i32::try_from(-ai)
                .map_err(|_| format!("qubo field {i} overflows i32"))?;
            if hv != 0 {
                model.set_h(i, hv);
            }
        }
        Ok(Qubo { model, offset })
    }

    /// Parse qbsolv-style text: `c`/`#` comment lines, an optional
    /// `p qubo <topology> <maxNodes> <nNodes> <nCouplers>` header, then
    /// `i j value` entries (0-indexed; integer values; `i == j` =
    /// linear term). Without a header, `n` is the largest index + 1.
    pub fn parse(text: &str) -> Result<Qubo, String> {
        let mut n: Option<usize> = None;
        let mut entries: Vec<(usize, usize, i64)> = Vec::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('c') || line.starts_with('#') {
                continue;
            }
            let mut toks = line.split_whitespace();
            if line.starts_with('p') {
                // p qubo <topology> <maxNodes> <nNodes> <nCouplers>
                let kind = toks.nth(1).unwrap_or("");
                if kind != "qubo" {
                    return Err(format!("line {}: unsupported problem kind '{kind}'", ln + 1));
                }
                let max_nodes = toks
                    .nth(1)
                    .and_then(|t| t.parse::<usize>().ok())
                    .ok_or(format!("line {}: malformed qubo header", ln + 1))?;
                n = Some(max_nodes);
                continue;
            }
            let (i, j, v) = parse_entry(line).ok_or(format!(
                "line {}: expected 'i j value', got '{line}'",
                ln + 1
            ))?;
            entries.push((i, j, v));
        }
        let n = n.unwrap_or_else(|| {
            entries.iter().map(|&(i, j, _)| i.max(j) + 1).max().unwrap_or(0)
        });
        if n == 0 {
            return Err("qubo input has no entries".to_string());
        }
        Qubo::from_entries(n, &entries)
    }

    /// The original QUBO objective of a spin configuration
    /// (`x_i = (1 + s_i) / 2`).
    pub fn energy(&self, spins: &SpinVec) -> i64 {
        (self.model.energy(spins) + self.offset) / 4
    }

    /// The binary assignment a spin configuration encodes.
    pub fn assignment(spins: &SpinVec) -> Vec<u8> {
        (0..spins.len()).map(|i| if spins.get(i) > 0 { 1 } else { 0 }).collect()
    }
}

fn parse_entry(line: &str) -> Option<(usize, usize, i64)> {
    let mut toks = line.split_whitespace();
    let i = toks.next()?.parse().ok()?;
    let j = toks.next()?.parse().ok()?;
    let v = toks.next()?.parse().ok()?;
    if toks.next().is_some() {
        return None;
    }
    Some((i, j, v))
}

/// Parse the MaxCut `.mc` alias: optional `c`/`#` comments, a `n m`
/// header, then `m` lines `u v w` with 1-indexed endpoints — the
/// classic Gset/Biq-Mac layout.
pub fn parse_maxcut(text: &str) -> Result<MaxCut, String> {
    let mut header: Option<(usize, usize)> = None;
    let mut g: Option<crate::graph::Graph> = None;
    let mut edges_seen = 0usize;
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('c') || line.starts_with('#') {
            continue;
        }
        let toks: Vec<&str> = line.split_whitespace().collect();
        match header {
            None => {
                if toks.len() != 2 {
                    return Err(format!("line {}: expected 'n m' header", ln + 1));
                }
                let n: usize = toks[0].parse().map_err(|_| format!("line {}: bad n", ln + 1))?;
                let m: usize = toks[1].parse().map_err(|_| format!("line {}: bad m", ln + 1))?;
                header = Some((n, m));
                g = Some(crate::graph::Graph::empty(n));
            }
            Some((n, _)) => {
                if toks.len() != 3 {
                    return Err(format!("line {}: expected 'u v w' edge", ln + 1));
                }
                let u: u32 = toks[0].parse().map_err(|_| format!("line {}: bad u", ln + 1))?;
                let v: u32 = toks[1].parse().map_err(|_| format!("line {}: bad v", ln + 1))?;
                let w: i32 = toks[2].parse().map_err(|_| format!("line {}: bad w", ln + 1))?;
                if u < 1 || v < 1 || u as usize > n || v as usize > n || u == v {
                    return Err(format!("line {}: endpoint out of range", ln + 1));
                }
                g.as_mut().unwrap().add_edge(u - 1, v - 1, w);
                edges_seen += 1;
            }
        }
    }
    let (_, m) = header.ok_or("maxcut input has no header")?;
    if edges_seen != m {
        return Err(format!("maxcut header promised {m} edges, found {edges_seen}"));
    }
    Ok(MaxCut::new(g.unwrap()))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force QUBO minimum over all assignments.
    fn brute_min(n: usize, entries: &[(usize, usize, i64)]) -> i64 {
        let mut best = i64::MAX;
        for mask in 0..(1u32 << n) {
            let x = |i: usize| ((mask >> i) & 1) as i64;
            let mut e = 0i64;
            for &(i, j, v) in entries {
                e += if i == j { v * x(i) } else { v * x(i) * x(j) };
            }
            best = best.min(e);
        }
        best
    }

    #[test]
    fn conversion_preserves_objective_on_all_configurations() {
        let entries: Vec<(usize, usize, i64)> =
            vec![(0, 0, -3), (1, 1, 2), (2, 2, -1), (0, 1, 4), (1, 2, -5), (0, 2, 1), (2, 0, 2)];
        let q = Qubo::from_entries(3, &entries).unwrap();
        for mask in 0..8u32 {
            let spins: Vec<i8> =
                (0..3).map(|i| if (mask >> i) & 1 == 1 { 1 } else { -1 }).collect();
            let s = SpinVec::from_spins(&spins);
            let x = |i: usize| ((mask >> i) & 1) as i64;
            let mut direct = 0i64;
            for &(i, j, v) in &entries {
                direct += if i == j { v * x(i) } else { v * x(i) * x(j) };
            }
            assert_eq!(q.energy(&s), direct, "mask {mask:03b}");
        }
    }

    #[test]
    fn ising_ground_state_is_qubo_minimum() {
        let entries: Vec<(usize, usize, i64)> =
            vec![(0, 0, 1), (1, 1, -2), (2, 2, 3), (3, 3, -1), (0, 1, -4), (1, 2, 2), (2, 3, -3)];
        let q = Qubo::from_entries(4, &entries).unwrap();
        let (idx, h_min) = crate::problems::landscape::ground_state(&q.model);
        let spins = crate::problems::landscape::config_of_index(4, idx);
        assert_eq!((h_min + q.offset) / 4, brute_min(4, &entries));
        assert_eq!(q.energy(&spins), brute_min(4, &entries));
    }

    #[test]
    fn parses_qbsolv_text_round_trip() {
        let text = "\
c toy instance
p qubo 0 4 4 3
0 0 -3
1 1 2
0 1 4
2 3 -5
";
        let q = Qubo::parse(text).unwrap();
        assert_eq!(q.model.len(), 4);
        // Same instance via the entry API must give the same model.
        let q2 = Qubo::from_entries(
            4,
            &[(0, 0, -3), (1, 1, 2), (0, 1, 4), (2, 3, -5)],
        )
        .unwrap();
        assert_eq!(q.offset, q2.offset);
        assert_eq!(q.model.j_matrix(), q2.model.j_matrix());
        assert_eq!(q.model.h_vec(), q2.model.h_vec());
    }

    #[test]
    fn parse_errors_are_reported() {
        assert!(Qubo::parse("").is_err());
        assert!(Qubo::parse("0 0\n").is_err());
        assert!(Qubo::parse("p maxsat 0 4 4 1\n0 0 1\n").is_err());
    }

    #[test]
    fn maxcut_alias_parses_gset_layout() {
        let text = "\
# triangle plus pendant
4 4
1 2 1
2 3 1
1 3 1
3 4 2
";
        let p = parse_maxcut(text).unwrap();
        assert_eq!(p.model().len(), 4);
        assert_eq!(p.w_total(), 5);
        // Optimal cut: {3} vs rest cuts edges 2-3, 1-3, 3-4 = 4.
        let (idx, e) = crate::problems::landscape::ground_state(p.model());
        let gs = crate::problems::landscape::config_of_index(4, idx);
        assert_eq!(p.cut_of_energy(e), 4);
        assert_eq!(p.cut_value(&gs), 4);
        assert!(parse_maxcut("4 2\n1 2 1\n").is_err()); // edge count mismatch
    }
}
