//! Travelling-salesman ↔ Ising encoding (paper §III-A names TSP as a
//! target workload; Lucas 2014 §7 construction).
//!
//! City `c` at tour position `p` is a binary variable `x_{c,p}`; spin
//! `s = 2x − 1`. The QUBO objective
//!
//! `A·Σ_c (Σ_p x_{c,p} − 1)² + A·Σ_p (Σ_c x_{c,p} − 1)² +
//!  B·Σ_{c,c'} d(c,c') Σ_p x_{c,p}·x_{c',p+1}`
//!
//! is expanded into Ising couplings/fields with integer arithmetic
//! (coefficients scaled by 4 to stay integral). With `A > B·max_d·n`
//! every constraint-satisfying assignment dominates, and the ground
//! state is the optimal tour.

use crate::ising::{IsingModel, SpinVec};

/// A TSP instance over an n×n distance matrix (symmetric, zero diag).
pub struct Tsp {
    pub n: usize,
    pub dist: Vec<i32>,
    model: IsingModel,
    pub a: i32,
    pub b: i32,
}

impl Tsp {
    /// Encode with penalty `A` (constraints) and weight `B` (tour
    /// length); `with_defaults` picks `A` safely.
    pub fn new(n: usize, dist: Vec<i32>, a: i32, b: i32) -> Self {
        assert_eq!(dist.len(), n * n);
        let nn = n * n; // one spin per (city, position)
        let var = |c: usize, p: usize| c * n + p;
        // Build in QUBO space: Q[u][v] (u ≤ v), linear L[u], then convert.
        let mut q = vec![0i64; nn * nn];
        let mut l = vec![0i64; nn];
        let mut add_q = |u: usize, v: usize, w: i64| {
            let (u, v) = if u <= v { (u, v) } else { (v, u) };
            q[u * nn + v] += w;
        };
        // Row constraints: each city in exactly one position.
        for c in 0..n {
            for p in 0..n {
                // (Σx − 1)² = Σx² − 2Σx + 1 with x² = x ⇒ linear −A per
                // variable (+A from x², −2A from the cross term).
                l[var(c, p)] += -2 * a as i64;
                l[var(c, p)] += a as i64;
                for p2 in (p + 1)..n {
                    add_q(var(c, p), var(c, p2), 2 * a as i64);
                }
            }
        }
        // Column constraints: each position holds exactly one city.
        for p in 0..n {
            for c in 0..n {
                l[var(c, p)] += -2 * a as i64;
                l[var(c, p)] += a as i64;
                for c2 in (c + 1)..n {
                    add_q(var(c, p), var(c2, p), 2 * a as i64);
                }
            }
        }
        // Tour length: consecutive positions (cyclic).
        for c in 0..n {
            for c2 in 0..n {
                if c == c2 {
                    continue;
                }
                let d = dist[c * n + c2] as i64;
                if d == 0 {
                    continue;
                }
                for p in 0..n {
                    let p_next = (p + 1) % n;
                    add_q(var(c, p), var(c2, p_next), b as i64 * d);
                }
            }
        }
        // QUBO → Ising: x = (s+1)/2. Scale everything by 4 to keep the
        // coefficients integral: 4·x_u·x_v = (s_u+1)(s_v+1)
        //                       = s_u s_v + s_u + s_v + 1.
        let mut model = IsingModel::zeros(nn);
        let mut h = vec![0i64; nn];
        for u in 0..nn {
            h[u] += 2 * l[u]; // 4·x = 2s + 2
            for v in (u + 1)..nn {
                let w = q[u * nn + v];
                if w == 0 {
                    continue;
                }
                // H contribution +w·s_u·s_v ⇒ J -= w (H = −ΣJ s s).
                model.add_j(u, v, -(w as i32));
                h[u] += w;
                h[v] += w;
            }
        }
        for (u, &hv) in h.iter().enumerate() {
            // H contribution +h·s ⇒ field term −h (H = −Σ h_i s_i).
            model.set_h(u, -(hv as i32));
        }
        Self { n, dist, model, a, b }
    }

    /// Encode with an automatically safe constraint penalty.
    pub fn with_defaults(n: usize, dist: Vec<i32>) -> Self {
        let max_d = dist.iter().copied().max().unwrap_or(1).max(1);
        let b = 1;
        let a = b * max_d * n as i32 + 1;
        Self::new(n, dist, a, b)
    }

    /// The Ising encoding (n² spins).
    pub fn model(&self) -> &IsingModel {
        &self.model
    }

    /// Decode a configuration into a tour if it satisfies the one-hot
    /// constraints; `None` otherwise.
    pub fn decode(&self, s: &SpinVec) -> Option<Vec<usize>> {
        let n = self.n;
        let mut tour = vec![usize::MAX; n];
        for p in 0..n {
            let mut found = None;
            for c in 0..n {
                if s.get(c * n + p) == 1 {
                    if found.is_some() {
                        return None; // two cities in one slot
                    }
                    found = Some(c);
                }
            }
            tour[p] = found?;
        }
        let mut seen = vec![false; n];
        for &c in &tour {
            if seen[c] {
                return None;
            }
            seen[c] = true;
        }
        Some(tour)
    }

    /// Cyclic tour length.
    pub fn tour_length(&self, tour: &[usize]) -> i64 {
        (0..tour.len())
            .map(|p| self.dist[tour[p] * self.n + tour[(p + 1) % tour.len()]] as i64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineConfig, Mode, Schedule, SnowballEngine};

    fn square_instance() -> Tsp {
        // 4 cities on a unit square (scaled): optimal tour = perimeter 40.
        let d = |a: (i32, i32), b: (i32, i32)| -> i32 {
            (((a.0 - b.0).pow(2) + (a.1 - b.1).pow(2)) as f64).sqrt().round() as i32
        };
        let pts = [(0, 0), (10, 0), (10, 10), (0, 10)];
        let mut dist = vec![0i32; 16];
        for i in 0..4 {
            for j in 0..4 {
                dist[i * 4 + j] = d(pts[i], pts[j]);
            }
        }
        Tsp::with_defaults(4, dist)
    }

    #[test]
    fn valid_tour_energy_ordering() {
        let tsp = square_instance();
        // Encode two tours as configurations and compare energies:
        // perimeter (optimal, length 40) vs crossed (length ~48).
        let encode = |tour: &[usize]| {
            let mut spins = vec![-1i8; 16];
            for (p, &c) in tour.iter().enumerate() {
                spins[c * 4 + p] = 1;
            }
            SpinVec::from_spins(&spins)
        };
        let good = encode(&[0, 1, 2, 3]);
        let bad = encode(&[0, 2, 1, 3]);
        assert_eq!(tsp.decode(&good), Some(vec![0, 1, 2, 3]));
        assert_eq!(tsp.tour_length(&[0, 1, 2, 3]), 40);
        assert!(tsp.tour_length(&[0, 2, 1, 3]) > 40);
        assert!(
            tsp.model().energy(&good) < tsp.model().energy(&bad),
            "shorter tour must have lower energy"
        );
        // Constraint violations cost more than any tour.
        let mut broken = good.clone();
        broken.flip(0);
        assert!(tsp.model().energy(&broken) > tsp.model().energy(&bad));
    }

    #[test]
    fn annealer_finds_a_valid_short_tour() {
        let tsp = square_instance();
        let cfg = EngineConfig {
            mode: Mode::RouletteWheel,
            datapath: crate::engine::Datapath::Dense,
            selector: crate::engine::SelectorKind::Fenwick,
            schedule: Schedule::Geometric { t0: 60.0, t1: 0.2 },
            steps: 60_000,
            seed: 5,
            planes: None,
            trace_stride: 0,
            shards: 1,
            pin_lanes: false,
            local_rows: false,
        };
        let mut e = SnowballEngine::new(tsp.model(), cfg);
        let r = e.run();
        let tour = tsp.decode(&r.best_spins).expect("annealer must satisfy constraints");
        assert_eq!(tsp.tour_length(&tour), 40, "must find the optimal square tour");
    }

    #[test]
    fn decode_rejects_invalid() {
        let tsp = square_instance();
        assert!(tsp.decode(&SpinVec::all_down(16)).is_none());
        assert!(tsp.decode(&SpinVec::all_up(16)).is_none());
    }
}
