//! Ancilla-based coupling bit-width reduction (paper §III-C, [42]).
//!
//! Hardware with only low-precision couplers can represent a
//! high-precision `J_ij` by splitting it across auxiliary spins that are
//! chained to the originals — at the cost of more spins and denser
//! connectivity, "directly hurting scalability and time-to-solution"
//! (§III-C). This module implements the split so that cost is
//! measurable, and Snowball's bit-plane alternative can be compared
//! against it quantitatively.
//!
//! Construction: a coupling with `|J| > Jmax` is decomposed as
//! `J = Σ_k c_k` with `|c_k| ≤ Jmax`. The first part `c_0` stays on the
//! original pair (i, j); each further part `c_k` is carried by an
//! ancilla `a_k` that is ferromagnetically locked to spin `i` (strength
//! `F`) and coupled to `j` with `c_k`. In the locked subspace
//! (`s_{a_k} = s_i`, enforced for `F` large enough) the effective
//! Hamiltonian equals the original.

use crate::ising::{IsingModel, SpinVec};

/// Result of an ancilla reduction.
pub struct Reduced {
    pub model: IsingModel,
    /// Original spin count (ancillas are indices ≥ this).
    pub original_n: usize,
    /// `ancilla[k] = (ancilla index, locked-to spin)`.
    pub ancillas: Vec<(usize, usize)>,
    /// Lock strength used.
    pub lock: i32,
}

/// Reduce a model so every coupling magnitude is ≤ `j_max`.
pub fn reduce_bitwidth(model: &IsingModel, j_max: i32) -> Reduced {
    assert!(j_max >= 1);
    let n = model.len();
    // Count ancillas needed: each oversized |J| needs ceil(|J|/Jmax) - 1.
    let mut extra = Vec::new(); // (i, j, leftover parts)
    for i in 0..n {
        for j in (i + 1)..n {
            let v = model.j(i, j);
            if v.abs() > j_max {
                extra.push((i, j, v));
            }
        }
    }
    let total_parts: usize =
        extra.iter().map(|&(_, _, v)| (v.abs() as usize).div_ceil(j_max as usize) - 1).sum();
    let big_n = n + total_parts;
    // Lock strength: must exceed the energy any single ancilla's other
    // couplings can gain by breaking the chain: |c_k| ≤ j_max, plus h=0
    // on ancillas → F > j_max suffices with margin 2×.
    let lock = 2 * j_max + 1;
    let mut out = IsingModel::zeros(big_n);
    for i in 0..n {
        out.set_h(i, model.h(i));
        for j in (i + 1)..n {
            let v = model.j(i, j);
            if v != 0 && v.abs() <= j_max {
                out.set_j(i, j, v);
            }
        }
    }
    let mut next = n;
    let mut ancillas = Vec::new();
    for (i, j, v) in extra {
        let sign = v.signum();
        let mut rem = v.abs();
        // First chunk on the original pair.
        let c0 = rem.min(j_max);
        out.set_j(i, j, sign * c0);
        rem -= c0;
        while rem > 0 {
            let c = rem.min(j_max);
            rem -= c;
            let a = next;
            next += 1;
            out.set_j(a, i, lock); // ferromagnetic lock to i
            out.set_j(a, j, sign * c); // carries this chunk
            ancillas.push((a, i));
        }
    }
    Reduced { model: out, original_n: n, ancillas, lock }
}

impl Reduced {
    /// Extend an original configuration with locked ancillas.
    pub fn extend(&self, s: &SpinVec) -> SpinVec {
        assert_eq!(s.len(), self.original_n);
        let mut spins: Vec<i8> = s.to_spins();
        spins.resize(self.model.len(), 1);
        for &(a, i) in &self.ancillas {
            spins[a] = s.get(i);
        }
        SpinVec::from_spins(&spins)
    }

    /// Energy offset between reduced (locked) and original models:
    /// every locked ancilla contributes `−lock` (chain satisfied).
    pub fn offset(&self) -> i64 {
        -(self.lock as i64) * self.ancillas.len() as i64
    }

    /// Spin-count inflation factor — the §III-C scalability cost.
    pub fn inflation(&self) -> f64 {
        self.model.len() as f64 / self.original_n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::StatelessRng;
    use crate::testutil::gen;

    #[test]
    fn reduced_energies_match_in_locked_subspace() {
        let rng = StatelessRng::new(31);
        let m = gen::model(&rng, 8, 13); // couplings up to ±13
        let red = reduce_bitwidth(&m, 3);
        // Every COUPLING magnitude is ≤ lock (fields are untouched by
        // the reduction and may exceed it).
        let max_j = (0..red.model.len())
            .flat_map(|i| red.model.j_row(i).iter().map(|v| v.abs()))
            .max()
            .unwrap();
        assert!(max_j <= red.lock);
        for i in 0..red.original_n {
            for j in 0..red.original_n {
                if i != j {
                    assert!(red.model.j(i, j).abs() <= 3, "original pair overweight");
                }
            }
        }
        for trial in 0..20u64 {
            let s = gen::spins(&rng.child(trial), 8);
            let e_orig = m.energy(&s);
            let e_red = red.model.energy(&red.extend(&s));
            assert_eq!(e_red - red.offset(), e_orig, "trial {trial}");
        }
    }

    #[test]
    fn ground_state_is_preserved() {
        // Small instance: check argmin matches via enumeration.
        let mut m = IsingModel::zeros(3);
        m.set_j(0, 1, 7);
        m.set_j(1, 2, -5);
        m.set_h(0, 2);
        let red = reduce_bitwidth(&m, 2);
        let (_, e_orig) = crate::problems::landscape::ground_state(&m);
        let (_, e_red) = crate::problems::landscape::ground_state(&red.model);
        assert_eq!(e_red - red.offset(), e_orig, "locked optimum must match");
    }

    #[test]
    fn inflation_grows_with_precision_gap() {
        let rng = StatelessRng::new(37);
        let m = gen::model(&rng, 10, 40);
        let tight = reduce_bitwidth(&m, 1);
        let loose = reduce_bitwidth(&m, 16);
        assert!(tight.inflation() > loose.inflation());
        assert!(tight.inflation() > 2.0, "1-bit hardware must inflate heavily");
        // Snowball's bit-plane store needs ZERO extra spins for the same
        // precision — the §III-C comparison in one assert.
        assert_eq!(crate::bitplane::BitPlanes::encode(&m, None).len(), 10);
    }

    #[test]
    fn no_op_when_precision_suffices() {
        let rng = StatelessRng::new(41);
        let m = gen::model(&rng, 6, 3);
        let red = reduce_bitwidth(&m, 3);
        assert_eq!(red.model.len(), 6);
        assert_eq!(red.inflation(), 1.0);
        assert!(red.ancillas.is_empty());
    }
}
