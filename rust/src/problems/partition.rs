//! Balanced graph partitioning ↔ Ising (paper §II-A, Lucas-style encoding).
//!
//! Minimize the cut weight subject to a balanced bipartition:
//!
//! `C(s) = A·(Σ_i s_i)² + B·cut(s)`
//!
//! Expanding `(Σ s)² = N + 2 Σ_{i<j} s_i s_j` and
//! `cut = Σ_{e=(i,j)} w_e (1 − s_i s_j)/2`, the spin-dependent part is
//! `Σ_{i<j} (2A − B·w_ij/2·[ij∈E]... ` — to keep integer coefficients we
//! scale by 2: `H(s) = −Σ J_ij s_i s_j` with
//! `J_ij = −4A + B·w_ij` (edge pairs) and `J_ij = −4A` (non-edges),
//! matching `2·C(s)` up to an additive constant. Choosing
//! `B·w > 0` rewards keeping heavy edges uncut, `A` enforces balance.

use crate::graph::Graph;
use crate::ising::{IsingModel, SpinVec};

/// A balanced-bipartition problem with its Ising encoding.
pub struct GraphPartition {
    pub graph: Graph,
    model: IsingModel,
    /// Balance penalty A (per the objective above).
    pub a: i32,
    /// Cut weight B.
    pub b: i32,
}

impl GraphPartition {
    /// Encode with penalty weights `a` (balance) and `b` (cut). A common
    /// safe choice is `a ≥ b·max_degree/8 + 1` so imbalance is never
    /// profitable; `with_defaults` picks that automatically.
    pub fn new(graph: Graph, a: i32, b: i32) -> Self {
        assert!(a > 0 && b > 0);
        let n = graph.n;
        let mut model = IsingModel::zeros(n);
        for i in 0..n as u32 {
            for k in (i + 1)..n as u32 {
                model.set_j(i as usize, k as usize, -4 * a);
            }
        }
        for e in &graph.edges {
            model.add_j(e.u as usize, e.v as usize, b * e.w);
        }
        Self { graph, model, a, b }
    }

    /// Encode with an automatically chosen balance penalty.
    pub fn with_defaults(graph: Graph) -> Self {
        let max_deg = graph.degrees().iter().copied().max().unwrap_or(0) as i32;
        let b = 2;
        let a = (b * max_deg) / 8 + 1;
        Self::new(graph, a, b)
    }

    /// The Ising encoding.
    pub fn model(&self) -> &IsingModel {
        &self.model
    }

    /// Cut weight of the bipartition induced by `s`.
    pub fn cut_value(&self, s: &SpinVec) -> i64 {
        self.graph
            .edges
            .iter()
            .filter(|e| s.get(e.u as usize) != s.get(e.v as usize))
            .map(|e| e.w as i64)
            .sum()
    }

    /// Imbalance `|Σ s_i|` (0 means perfectly balanced).
    pub fn imbalance(&self, s: &SpinVec) -> i64 {
        s.magnetization().abs()
    }

    /// The scaled objective `2·C(s) = 2A(Σs)² + 2B·cut` recomputed from
    /// the graph (verification oracle, independent of the encoding).
    pub fn objective(&self, s: &SpinVec) -> i64 {
        let m = s.magnetization();
        2 * self.a as i64 * m * m + 2 * self.b as i64 * self.cut_value(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::StatelessRng;

    #[test]
    fn encoding_matches_objective_up_to_constant() {
        let rng = StatelessRng::new(31);
        let g = crate::graph::generators::erdos_renyi(20, 60, &[1, 2, 3], &rng);
        let p = GraphPartition::new(g, 3, 2);
        // H(s) and objective(s) must differ by a constant independent of s.
        let s0 = SpinVec::random(20, &rng.child(0));
        let c = p.objective(&s0) - p.model().energy(&s0);
        for t in 1..20u64 {
            let s = SpinVec::random(20, &rng.child(t));
            assert_eq!(
                p.objective(&s) - p.model().energy(&s),
                c,
                "encoding does not track the objective"
            );
        }
    }

    #[test]
    fn balanced_cut_beats_unbalanced() {
        // Two 4-cliques joined by one edge: optimum is clique vs clique.
        let mut g = Graph::empty(8);
        for u in 0..4u32 {
            for v in (u + 1)..4 {
                g.add_edge(u, v, 1);
                g.add_edge(u + 4, v + 4, 1);
            }
        }
        g.add_edge(0, 4, 1);
        let p = GraphPartition::with_defaults(g);
        let good = SpinVec::from_spins(&[1, 1, 1, 1, -1, -1, -1, -1]);
        let bad = SpinVec::from_spins(&[1, -1, 1, -1, 1, -1, 1, -1]);
        assert!(p.objective(&good) < p.objective(&bad));
        assert_eq!(p.cut_value(&good), 1);
        assert_eq!(p.imbalance(&good), 0);
    }
}
