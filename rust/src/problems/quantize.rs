//! Coupling-coefficient quantization (paper §III-C, Fig. 8).
//!
//! Hardware with limited coupling precision must coarsely quantize `J`
//! and `h`. The paper illustrates this with a k-bit *arithmetic right
//! shift*, which distorts the energy landscape and can change the ground
//! state — the motivation for Snowball's scalable bit-plane precision.

use crate::ising::IsingModel;

/// Quantize a model by an arithmetic right shift of `bits` on every
/// coupling and field (Fig. 8's transformation).
pub fn arithmetic_shift(model: &IsingModel, bits: u32) -> IsingModel {
    let n = model.len();
    let mut q = IsingModel::zeros(n);
    for i in 0..n {
        for k in (i + 1)..n {
            let v = model.j(i, k) >> bits;
            if v != 0 {
                q.set_j(i, k, v);
            }
        }
        q.set_h(i, model.h(i) >> bits);
    }
    q
}

/// Clamp-quantize to `bits`-bit signed range [−2^(bits−1), 2^(bits−1)−1]
/// — models hardware that saturates rather than shifts.
pub fn saturate(model: &IsingModel, bits: u32) -> IsingModel {
    assert!(bits >= 1 && bits <= 31);
    let lo = -(1i32 << (bits - 1));
    let hi = (1i32 << (bits - 1)) - 1;
    let n = model.len();
    let mut q = IsingModel::zeros(n);
    for i in 0..n {
        for k in (i + 1)..n {
            let v = model.j(i, k).clamp(lo, hi);
            if v != 0 {
                q.set_j(i, k, v);
            }
        }
        q.set_h(i, model.h(i).clamp(lo, hi));
    }
    q
}

/// Number of bits needed to represent every coefficient exactly in signed
/// magnitude (the `B` the bit-plane store needs; paper Eq. 13).
pub fn required_bits(model: &IsingModel) -> u32 {
    let m = model.max_abs_coeff();
    if m == 0 {
        1
    } else {
        32 - (m as u32).leading_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ising::SpinVec;

    fn model_with_range() -> IsingModel {
        let mut m = IsingModel::zeros(4);
        m.set_j(0, 1, 7);
        m.set_j(1, 2, -5);
        m.set_j(2, 3, 12);
        m.set_h(0, -9);
        m
    }

    #[test]
    fn shift_matches_integer_semantics() {
        let q = arithmetic_shift(&model_with_range(), 2);
        assert_eq!(q.j(0, 1), 1); // 7 >> 2
        assert_eq!(q.j(1, 2), -2); // -5 >> 2 (arithmetic)
        assert_eq!(q.j(2, 3), 3);
        assert_eq!(q.h(0), -3); // -9 >> 2
    }

    #[test]
    fn quantization_distorts_landscape() {
        // Fig 8's point: the quantized model ranks configurations
        // differently; check energies are not a constant offset apart.
        let m = model_with_range();
        let q = arithmetic_shift(&m, 2);
        let s1 = SpinVec::from_spins(&[1, 1, 1, 1]);
        let s2 = SpinVec::from_spins(&[1, -1, 1, -1]);
        let d_orig = m.energy(&s1) - m.energy(&s2);
        let d_quant = q.energy(&s1) - q.energy(&s2);
        assert_ne!(d_orig, d_quant);
    }

    #[test]
    fn saturate_clamps() {
        let q = saturate(&model_with_range(), 4); // range [-8, 7]
        assert_eq!(q.j(2, 3), 7);
        assert_eq!(q.h(0), -8);
        assert_eq!(q.j(1, 2), -5);
    }

    #[test]
    fn required_bits_covers_max() {
        assert_eq!(required_bits(&model_with_range()), 4); // max |c| = 12
        let z = IsingModel::zeros(3);
        assert_eq!(required_bits(&z), 1);
    }
}
