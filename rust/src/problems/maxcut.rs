//! Max-Cut ↔ Ising mapping (paper §II-A/§II-B).
//!
//! With `J_ij = −w_ij` and `h = 0`, the Hamiltonian is
//! `H(s) = Σ_{i<j} w_ij s_i s_j`, and the cut induced by the ± partition is
//! `cut(s) = (W_tot − H(s)) / 2` where `W_tot = Σ w_ij`. Minimizing H
//! maximizes the cut; this is the encoding Snowball programs into its
//! coupler planes.

use crate::graph::Graph;
use crate::ising::{IsingModel, SpinVec};

/// A Max-Cut problem with its Ising encoding.
pub struct MaxCut {
    pub graph: Graph,
    model: IsingModel,
    w_total: i64,
}

impl MaxCut {
    /// Encode a weighted graph as an Ising instance.
    pub fn new(graph: Graph) -> Self {
        let mut model = IsingModel::zeros(graph.n);
        for e in &graph.edges {
            model.add_j(e.u as usize, e.v as usize, -e.w);
        }
        let w_total = graph.total_weight();
        Self { graph, model, w_total }
    }

    /// The Ising encoding.
    pub fn model(&self) -> &IsingModel {
        &self.model
    }

    /// Total edge weight `Σ w_e`.
    pub fn w_total(&self) -> i64 {
        self.w_total
    }

    /// Cut value from an Ising energy: `cut = (W_tot − H)/2`.
    pub fn cut_of_energy(&self, energy: i64) -> i64 {
        debug_assert_eq!((self.w_total - energy) % 2, 0);
        (self.w_total - energy) / 2
    }

    /// Ising energy of a given cut value (inverse of `cut_of_energy`).
    pub fn energy_of_cut(&self, cut: i64) -> i64 {
        self.w_total - 2 * cut
    }

    /// Direct cut evaluation `Σ_{(u,v)∈E : s_u ≠ s_v} w_uv` — the
    /// verification oracle (Θ(|E|), independent of the Ising encoding).
    pub fn cut_value(&self, s: &SpinVec) -> i64 {
        self.graph
            .edges
            .iter()
            .filter(|e| s.get(e.u as usize) != s.get(e.v as usize))
            .map(|e| e.w as i64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::StatelessRng;

    fn triangle() -> Graph {
        let mut g = Graph::empty(3);
        g.add_edge(0, 1, 1);
        g.add_edge(1, 2, 1);
        g.add_edge(0, 2, 1);
        g
    }

    #[test]
    fn triangle_max_cut_is_two() {
        let p = MaxCut::new(triangle());
        // best: one vertex vs other two → cut = 2
        let s = SpinVec::from_spins(&[1, -1, -1]);
        assert_eq!(p.cut_value(&s), 2);
        assert_eq!(p.cut_of_energy(p.model().energy(&s)), 2);
    }

    #[test]
    fn cut_energy_identity_holds_on_random_configs() {
        let rng = StatelessRng::new(23);
        let g = crate::graph::generators::erdos_renyi(40, 200, &[-1, 1], &rng);
        let p = MaxCut::new(g);
        for t in 0..25u64 {
            let s = SpinVec::random(40, &rng.child(t));
            let via_energy = p.cut_of_energy(p.model().energy(&s));
            assert_eq!(via_energy, p.cut_value(&s));
        }
    }

    #[test]
    fn energy_cut_inverse() {
        let p = MaxCut::new(triangle());
        for cut in [-3i64, 0, 2, 3] {
            assert_eq!(p.cut_of_energy(p.energy_of_cut(cut)), cut);
        }
    }
}
