//! Comparison solvers: every algorithm Snowball is benchmarked against in
//! Tables II and III (paper §V), reimplemented from their original
//! descriptions (DESIGN.md §3 documents interpretation choices).

pub mod checkerboard;
pub mod cim;
pub mod common;
pub mod neal;
pub mod reaim;
pub mod sb;
pub mod statica;
pub mod tabu;

pub use checkerboard::Checkerboard;
pub use cim::Cim;
pub use common::{Best, Budget, ChainState, SolveCtl, SolveResult, Solver};
pub use neal::Neal;
pub use reaim::{ReAim, Variant};
pub use sb::SimulatedBifurcation;
pub use statica::Statica;
pub use tabu::Tabu;

use crate::engine::{Datapath, EngineConfig, Mode, Schedule, SelectorKind, SnowballEngine};
use crate::ising::IsingModel;
use crate::stop::StopCause;

/// Snowball itself, wrapped in the common [`Solver`] interface so the
/// Table II/III harnesses treat it uniformly. One "sweep" of budget maps
/// to N engine steps for RSA (one attempt each) and to N steps for RWA
/// (each step evaluates all N spins but commits one flip — the paper's
/// accounting, which is what makes the comparison fair in *steps*, while
/// the runtime figures capture the differing per-step cost).
pub struct SnowballSolver {
    pub mode: Mode,
    pub schedule: Schedule,
    /// Engine steps per budget sweep; default N-steps-per-sweep.
    pub steps_per_sweep: Option<u64>,
}

impl SnowballSolver {
    pub fn rsa() -> Self {
        Self {
            mode: Mode::RandomScan,
            schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
            steps_per_sweep: None,
        }
    }

    pub fn rwa() -> Self {
        Self {
            mode: Mode::RouletteWheel,
            schedule: Schedule::Geometric { t0: 8.0, t1: 0.05 },
            steps_per_sweep: None,
        }
    }
}

impl Solver for SnowballSolver {
    fn name(&self) -> &'static str {
        match self.mode {
            Mode::RandomScan => "RSA",
            Mode::RouletteWheel => "RWA",
            Mode::RouletteUniformized => "RWA-U",
        }
    }

    fn solve_ctl(&self, model: &IsingModel, budget: Budget, seed: u64, ctl: &common::SolveCtl) -> SolveResult {
        let n = model.len() as u64;
        let steps = match self.steps_per_sweep {
            Some(sps) => budget.sweeps * sps,
            None => budget.sweeps * n,
        };
        let cfg = EngineConfig {
            mode: self.mode,
            datapath: Datapath::Dense,
            selector: SelectorKind::Fenwick,
            schedule: self.schedule.clone(),
            steps,
            seed,
            planes: None,
            trace_stride: 0,
            shards: 1,
            pin_lanes: false,
            local_rows: false,
        };
        let mut engine = SnowballEngine::new(model, cfg);
        // The engine has no target notion of its own; target detection
        // (and upstream-token forwarding) rides the checkpoint callback:
        // a checkpoint whose incumbent satisfies `ctl` trips this run's
        // token, and the engine stops at its next stride check.
        let stride = (steps / 64).clamp(64, 65_536);
        let r = engine.run_session(ctl.stop_token(), None, stride, |ck| {
            if ctl.should_stop(ck.best_energy) {
                ctl.stop_token().trip(StopCause::Cancel);
            }
        });
        SolveResult {
            best_energy: r.best_energy,
            best_spins: r.best_spins,
            attempts: r.steps,
            wall: r.wall,
        }
    }
}

/// The full Table II solver line-up, in column order:
/// SFG MFG SFA MFA ASF AMF ASA Neal Tabu RWA RSA.
pub fn table2_lineup() -> Vec<Box<dyn Solver>> {
    let mut v: Vec<Box<dyn Solver>> = Vec::new();
    for r in ReAim::all() {
        v.push(Box::new(r));
    }
    v.push(Box::new(Neal::default()));
    v.push(Box::new(Tabu::default()));
    v.push(Box::new(SnowballSolver::rwa()));
    v.push(Box::new(SnowballSolver::rsa()));
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;
    use crate::rng::StatelessRng;

    #[test]
    fn lineup_matches_table2_column_order() {
        let names: Vec<&str> = table2_lineup().iter().map(|s| s.name()).collect();
        assert_eq!(
            names,
            vec!["SFG", "MFG", "SFA", "MFA", "ASF", "AMF", "ASA", "Neal", "Tabu", "RWA", "RSA"]
        );
    }

    #[test]
    fn snowball_solver_consistency() {
        let rng = StatelessRng::new(9);
        let p = MaxCut::new(generators::erdos_renyi(40, 160, &[-1, 1], &rng));
        for s in [SnowballSolver::rsa(), SnowballSolver::rwa()] {
            let r = s.solve(p.model(), Budget::sweeps(60), 3);
            assert_eq!(r.best_energy, p.model().energy(&r.best_spins), "{}", s.name());
        }
    }
}
