//! STATICA-style digital annealer (Yamamoto et al. [54]) — the
//! "all-spin-updates-at-once" CMOS comparator of Table III.
//!
//! STATICA evaluates every spin's flip probability from the *current*
//! configuration and commits updates synchronously. Naive synchronous
//! commits violate detailed balance and oscillate (paper §III-B);
//! STATICA tempers this by stochastically *gating* how many of the
//! candidate flips commit per iteration (its delta-driven spin-update
//! circuit commits a bounded expected number). We model that with a
//! per-spin commit probability `gamma / E[#candidates]`, keeping the
//! expected simultaneous flips near `gamma` — which both suppresses the
//! period-2 oscillation and matches the chip's reported behaviour of a
//! few flips per cycle.

use super::common::{Best, Budget, ChainState, SolveCtl, SolveResult, Solver};
use crate::engine::lut::{PwlLogistic, ONE_Q16};
use crate::ising::{IsingModel, SpinVec};
use crate::rng::{salt, StatelessRng};

/// Synchronized stochastic multi-spin annealer.
pub struct Statica {
    pub t0: f64,
    pub t1: f64,
    /// Target expected flips per iteration.
    pub gamma: f64,
}

impl Default for Statica {
    fn default() -> Self {
        Self { t0: 8.0, t1: 0.05, gamma: 4.0 }
    }
}

impl Solver for Statica {
    fn name(&self) -> &'static str {
        "STATICA"
    }

    fn solve_ctl(&self, model: &IsingModel, budget: Budget, seed: u64, ctl: &SolveCtl) -> SolveResult {
        let start = std::time::Instant::now();
        let n = model.len();
        let rng = StatelessRng::new(seed);
        let lut = PwlLogistic::default();
        let mut st = ChainState::new(model, SpinVec::random(n, &rng));
        let mut best = Best::new(&st);
        let iters = budget.sweeps.max(1);
        let mut attempts = 0u64;
        let mut p = vec![0u32; n];
        for it in 0..iters {
            if ctl.should_stop(best.energy) {
                break;
            }
            let frac = if iters == 1 { 1.0 } else { it as f64 / (iters - 1) as f64 };
            let temp = self.t0 * (self.t1 / self.t0).powf(frac);
            // Phase 1: evaluate all spins from the CURRENT configuration.
            let mut w: u64 = 0;
            for i in 0..n {
                attempts += 1;
                p[i] = lut.flip_prob_q16(st.delta_e(i), temp);
                w += p[i] as u64;
            }
            if w == 0 {
                continue;
            }
            // Gate so E[#flips] ≈ gamma (≥ 1 candidate always possible).
            let scale = (self.gamma * ONE_Q16 as f64 / w as f64).min(1.0);
            // Phase 2: synchronous commit of the gated candidate set.
            let mut to_flip: Vec<usize> = Vec::new();
            for i in 0..n {
                let gated = (p[i] as f64 * scale) as u32;
                let r = rng.u32(it, i as u64, salt::BASELINE) >> 16;
                if r < gated {
                    to_flip.push(i);
                }
            }
            for &i in &to_flip {
                st.flip(model, i); // commit; fields refresh as a batch
            }
            best.observe(&st);
        }
        SolveResult { best_energy: best.energy, best_spins: best.spins, attempts, wall: start.elapsed() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;

    #[test]
    fn statica_anneals() {
        let rng = StatelessRng::new(5);
        let p = MaxCut::new(generators::erdos_renyi(64, 300, &[-1, 1], &rng));
        let r = Statica::default().solve(p.model(), Budget::sweeps(600), 11);
        assert_eq!(r.best_energy, p.model().energy(&r.best_spins));
        assert!(r.best_energy < -60, "STATICA best {} too weak", r.best_energy);
    }

    #[test]
    fn no_period2_oscillation_on_antiferromagnet() {
        // The classic failure mode of naive all-spin updates: a 2-spin
        // antiferromagnet flips both spins forever. The gated commits
        // must still find the ground state (+1, -1) or (-1, +1).
        let mut m = IsingModel::zeros(2);
        m.set_j(0, 1, -1);
        let r = Statica::default().solve(&m, Budget::sweeps(200), 3);
        assert_eq!(r.best_energy, -1);
    }
}
