//! The ReAIM algorithm family (Chiang et al. [11]) — the seven
//! comparators SFG/MFG/SFA/MFA/ASF/AMF/ASA of Table II.
//!
//! The Snowball paper reimplements the algorithms benchmarked by ReAIM
//! "following the original descriptions and parameter settings" but does
//! not spell the variants out; we implement them as the natural product
//! the acronyms denote (documented per constructor, DESIGN.md §3):
//!
//! * **SFG** — Single-Flip Greedy: random site, flip iff ΔE < 0.
//! * **MFG** — Multi-Flip Greedy: synchronous flip of all ΔE < 0 sites,
//!   each gated at probability ½ to damp oscillation.
//! * **SFA** — Single-Flip Annealed: random site, Metropolis accept under
//!   a geometric temperature ladder.
//! * **MFA** — Multi-Flip Annealed: synchronous Glauber-gated flips under
//!   the same ladder (gate 1/⟨candidates⟩ like a massively parallel
//!   annealer's commit stage).
//! * **ASF** — Adaptive Single-Flip: SFA plus stall-triggered reheating
//!   (temperature doubles when no improvement for a window).
//! * **AMF** — Adaptive Multi-Flip: MFA plus the same reheating rule.
//! * **ASA** — Adaptive Simulated Annealing: SFA with random restarts
//!   from the best-so-far on stall (the "adaptive" restart strategy of
//!   ReRAM annealers).

use super::common::{Best, Budget, ChainState, SolveCtl, SolveResult, Solver};
use crate::engine::lut::PwlLogistic;
use crate::ising::{IsingModel, SpinVec};
use crate::rng::{salt, StatelessRng};

/// Which family member to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Sfg,
    Mfg,
    Sfa,
    Mfa,
    Asf,
    Amf,
    Asa,
}

/// A ReAIM-family solver.
pub struct ReAim {
    pub variant: Variant,
    pub t0: f64,
    pub t1: f64,
    /// Stall window (iterations without improvement) for the adaptive
    /// variants; 0 = auto (N).
    pub stall_window: u64,
}

impl ReAim {
    pub fn new(variant: Variant) -> Self {
        Self { variant, t0: 8.0, t1: 0.05, stall_window: 0 }
    }

    pub fn sfg() -> Self {
        Self::new(Variant::Sfg)
    }
    pub fn mfg() -> Self {
        Self::new(Variant::Mfg)
    }
    pub fn sfa() -> Self {
        Self::new(Variant::Sfa)
    }
    pub fn mfa() -> Self {
        Self::new(Variant::Mfa)
    }
    pub fn asf() -> Self {
        Self::new(Variant::Asf)
    }
    pub fn amf() -> Self {
        Self::new(Variant::Amf)
    }
    pub fn asa() -> Self {
        Self::new(Variant::Asa)
    }

    /// All seven variants in Table II column order.
    pub fn all() -> Vec<ReAim> {
        [Variant::Sfg, Variant::Mfg, Variant::Sfa, Variant::Mfa, Variant::Asf, Variant::Amf, Variant::Asa]
            .into_iter()
            .map(ReAim::new)
            .collect()
    }

    fn is_single_flip(&self) -> bool {
        matches!(self.variant, Variant::Sfg | Variant::Sfa | Variant::Asf | Variant::Asa)
    }

    fn is_greedy(&self) -> bool {
        matches!(self.variant, Variant::Sfg | Variant::Mfg)
    }

    fn is_adaptive(&self) -> bool {
        matches!(self.variant, Variant::Asf | Variant::Amf | Variant::Asa)
    }
}

impl Solver for ReAim {
    fn name(&self) -> &'static str {
        match self.variant {
            Variant::Sfg => "SFG",
            Variant::Mfg => "MFG",
            Variant::Sfa => "SFA",
            Variant::Mfa => "MFA",
            Variant::Asf => "ASF",
            Variant::Amf => "AMF",
            Variant::Asa => "ASA",
        }
    }

    fn solve_ctl(&self, model: &IsingModel, budget: Budget, seed: u64, ctl: &SolveCtl) -> SolveResult {
        let start = std::time::Instant::now();
        let n = model.len();
        let rng = StatelessRng::new(seed);
        let lut = PwlLogistic::default();
        let mut st = ChainState::new(model, SpinVec::random(n, &rng));
        let mut best = Best::new(&st);
        let stall_window = if self.stall_window == 0 { n as u64 } else { self.stall_window };
        let mut stall = 0u64;
        let mut reheat = 1.0f64;
        let mut attempts = 0u64;

        if self.is_single_flip() {
            let total = budget.attempts(n);
            for it in 0..total {
                if it % (n as u64).max(1) == 0 && ctl.should_stop(best.energy) {
                    break;
                }
                attempts += 1;
                let frac = if total <= 1 { 1.0 } else { it as f64 / (total - 1) as f64 };
                let temp = if self.is_greedy() {
                    0.0
                } else {
                    reheat * self.t0 * (self.t1 / self.t0).powf(frac)
                };
                let i = rng.below(it, 0, salt::SITE, n as u32) as usize;
                let de = st.delta_e(i);
                let accept = if temp <= 0.0 {
                    de < 0
                } else {
                    de <= 0 || rng.unit_f64(it, 1, salt::ACCEPT) < (-(de as f64) / temp).exp()
                };
                if accept {
                    st.flip(model, i);
                }
                let improved = st.energy < best.energy;
                best.observe(&st);
                if self.is_adaptive() {
                    if improved {
                        stall = 0;
                        reheat = 1.0;
                    } else {
                        stall += 1;
                        if stall >= stall_window {
                            stall = 0;
                            match self.variant {
                                Variant::Asa => {
                                    // Restart from best-so-far with a kick.
                                    st = ChainState::new(model, best.spins.clone());
                                    for _ in 0..(n / 8).max(1) {
                                        let k = rng.below(it, 2, salt::BASELINE, n as u32) as usize;
                                        st.flip(model, k);
                                    }
                                }
                                _ => reheat = (reheat * 2.0).min(16.0),
                            }
                        }
                    }
                }
            }
        } else {
            // Multi-flip variants: one iteration = one synchronous pass.
            let iters = budget.sweeps.max(1);
            let mut p = vec![0u32; n];
            for it in 0..iters {
                if ctl.should_stop(best.energy) {
                    break;
                }
                let frac = if iters <= 1 { 1.0 } else { it as f64 / (iters - 1) as f64 };
                let temp = if self.is_greedy() {
                    0.0
                } else {
                    reheat * self.t0 * (self.t1 / self.t0).powf(frac)
                };
                // Evaluate all spins from the current configuration.
                let mut candidates = 0u64;
                for i in 0..n {
                    attempts += 1;
                    let de = st.delta_e(i);
                    p[i] = if temp <= 0.0 {
                        if de < 0 {
                            1 << 16
                        } else {
                            0
                        }
                    } else {
                        lut.flip_prob_q16(de, temp)
                    };
                    if p[i] > 0 {
                        candidates += 1;
                    }
                }
                if candidates == 0 {
                    continue;
                }
                // Gate: greedy uses probability 1/2; annealed gates to an
                // expected O(1) commits over the candidate set.
                let gate = if self.is_greedy() {
                    0.5
                } else {
                    (4.0 / candidates as f64).min(1.0)
                };
                for i in 0..n {
                    if p[i] == 0 {
                        continue;
                    }
                    let gated = (p[i] as f64 * gate) as u32;
                    let r = rng.u32(it, i as u64, salt::BASELINE) >> 16;
                    if r < gated {
                        st.flip(model, i);
                    }
                }
                let improved = st.energy < best.energy;
                best.observe(&st);
                if self.is_adaptive() {
                    if improved {
                        stall = 0;
                        reheat = 1.0;
                    } else {
                        stall += 1;
                        if stall >= (stall_window / n as u64).max(8) {
                            stall = 0;
                            reheat = (reheat * 2.0).min(16.0);
                        }
                    }
                }
            }
        }
        SolveResult { best_energy: best.energy, best_spins: best.spins, attempts, wall: start.elapsed() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;

    fn instance() -> MaxCut {
        let rng = StatelessRng::new(6);
        MaxCut::new(generators::erdos_renyi(48, 220, &[-1, 1], &rng))
    }

    #[test]
    fn all_variants_produce_consistent_results() {
        let p = instance();
        for solver in ReAim::all() {
            let r = solver.solve(p.model(), Budget::sweeps(100), 13);
            assert_eq!(
                r.best_energy,
                p.model().energy(&r.best_spins),
                "{} returned inconsistent energy",
                solver.name()
            );
            assert!(r.best_energy < 0, "{} found nothing", solver.name());
        }
    }

    #[test]
    fn annealed_beats_greedy_on_average() {
        let p = instance();
        let mut greedy_sum = 0i64;
        let mut annealed_sum = 0i64;
        for seed in 0..5 {
            greedy_sum += ReAim::sfg().solve(p.model(), Budget::sweeps(150), seed).best_energy;
            annealed_sum += ReAim::sfa().solve(p.model(), Budget::sweeps(150), seed).best_energy;
        }
        assert!(
            annealed_sum <= greedy_sum,
            "SFA ({annealed_sum}) should not lose to SFG ({greedy_sum}) on average"
        );
    }

    #[test]
    fn adaptive_restart_terminates() {
        // ASA on a tiny frustrated instance: just verify it runs its
        // budget and returns the exact optimum found by enumeration.
        let mut m = IsingModel::zeros(6);
        m.set_j(0, 1, 1);
        m.set_j(1, 2, 1);
        m.set_j(0, 2, 1);
        m.set_j(3, 4, -2);
        m.set_j(4, 5, 1);
        let (_, e_opt) = crate::problems::landscape::ground_state(&m);
        let r = ReAim::asa().solve(&m, Budget::sweeps(500), 21);
        assert_eq!(r.best_energy, e_opt);
    }
}
