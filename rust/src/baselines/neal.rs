//! Neal-style simulated annealing (D-Wave `dwave-neal` [15]), the CPU
//! baseline of Tables II/III.
//!
//! Matches `neal.SimulatedAnnealingSampler`'s core: sequential
//! single-spin Metropolis sweeps under a geometric inverse-temperature
//! (β) ladder from `beta_min` to `beta_max`, β stepped once per sweep.

use super::common::{Best, Budget, ChainState, SolveCtl, SolveResult, Solver};
use crate::ising::{IsingModel, SpinVec};
use crate::rng::{salt, StatelessRng};

/// Geometric-β simulated annealing.
pub struct Neal {
    pub beta_min: f64,
    pub beta_max: f64,
}

impl Default for Neal {
    fn default() -> Self {
        // dwave-neal's defaults scale β to the instance; these values
        // behave equivalently for the ±1-coupling benchmarks used here.
        Self { beta_min: 0.1, beta_max: 10.0 }
    }
}

impl Solver for Neal {
    fn name(&self) -> &'static str {
        "Neal"
    }

    fn solve_ctl(&self, model: &IsingModel, budget: Budget, seed: u64, ctl: &SolveCtl) -> SolveResult {
        let start = std::time::Instant::now();
        let n = model.len();
        let rng = StatelessRng::new(seed);
        let mut st = ChainState::new(model, SpinVec::random(n, &rng));
        let mut best = Best::new(&st);
        let sweeps = budget.sweeps.max(1);
        let ratio = self.beta_max / self.beta_min;
        let mut attempts = 0u64;
        for sweep in 0..sweeps {
            if ctl.should_stop(best.energy) {
                break;
            }
            let frac = if sweeps == 1 { 1.0 } else { sweep as f64 / (sweeps - 1) as f64 };
            let beta = self.beta_min * ratio.powf(frac);
            for i in 0..n {
                attempts += 1;
                let de = st.delta_e(i);
                // Metropolis: accept if ΔE ≤ 0 or rand < exp(−βΔE).
                let accept = de <= 0 || {
                    let r = rng.unit_f64(sweep, (i as u64) | (1 << 40), salt::BASELINE);
                    r < (-beta * de as f64).exp()
                };
                if accept {
                    st.flip(model, i);
                }
            }
            best.observe(&st);
        }
        SolveResult { best_energy: best.energy, best_spins: best.spins, attempts, wall: start.elapsed() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;

    #[test]
    fn anneal_improves_over_random() {
        let rng = StatelessRng::new(1);
        let p = MaxCut::new(generators::erdos_renyi(64, 300, &[-1, 1], &rng));
        let r = Neal::default().solve(p.model(), Budget::sweeps(200), 7);
        assert_eq!(r.best_energy, p.model().energy(&r.best_spins));
        assert!(r.best_energy < -60, "SA best energy {} too weak", r.best_energy);
        assert_eq!(r.attempts, 200 * 64);
    }

    #[test]
    fn deterministic_in_seed() {
        let rng = StatelessRng::new(2);
        let p = MaxCut::new(generators::erdos_renyi(32, 100, &[-1, 1], &rng));
        let a = Neal::default().solve(p.model(), Budget::sweeps(50), 3);
        let b = Neal::default().solve(p.model(), Budget::sweeps(50), 3);
        assert_eq!(a.best_energy, b.best_energy);
    }
}
