//! Shared infrastructure for the comparison solvers of Tables II/III.

use crate::ising::{IsingModel, SpinVec};
use crate::stop::{StopCause, StopToken};
use std::sync::Arc;
use std::time::Duration;

/// A compute budget expressed in sweeps (1 sweep = N single-spin update
/// attempts), the unit the annealing literature uses for fair comparison.
#[derive(Clone, Copy, Debug)]
pub struct Budget {
    pub sweeps: u64,
}

impl Budget {
    pub fn sweeps(sweeps: u64) -> Self {
        Self { sweeps }
    }

    /// Total single-spin attempts for an `n`-spin instance.
    pub fn attempts(&self, n: usize) -> u64 {
        self.sweeps * n as u64
    }
}

/// Outcome of a solver run.
#[derive(Clone, Debug)]
pub struct SolveResult {
    pub best_energy: i64,
    pub best_spins: SpinVec,
    /// Single-spin update attempts actually performed.
    pub attempts: u64,
    pub wall: Duration,
}

/// Cooperative run control for a [`Solver`]: a per-run [`StopToken`]
/// plus an optional target energy, checked by implementations once per
/// sweep (or equivalent outer iteration). The portfolio racer
/// (`crate::portfolio`) hands every contender one of these so losers
/// stop within a sweep of the winner finishing; standalone callers use
/// [`SolveCtl::free`] (what the default [`Solver::solve`] does) and are
/// unaffected.
pub struct SolveCtl {
    stop: Arc<StopToken>,
    /// An upstream (job-level) token whose cause is forwarded onto
    /// `stop` at the next [`SolveCtl::should_stop`] check — how a
    /// coordinator cancel/deadline reaches a racing contender.
    upstream: Option<Arc<StopToken>>,
    target: Option<i64>,
}

impl SolveCtl {
    /// Uncontrolled: fresh token, no target — the run always completes
    /// its full budget.
    pub fn free() -> Self {
        Self { stop: Arc::new(StopToken::new()), upstream: None, target: None }
    }

    pub fn new(stop: Arc<StopToken>, target: Option<i64>) -> Self {
        Self { stop, upstream: None, target }
    }

    pub fn with_upstream(
        stop: Arc<StopToken>,
        upstream: Arc<StopToken>,
        target: Option<i64>,
    ) -> Self {
        Self { stop, upstream: Some(upstream), target }
    }

    /// This run's own token (what a racer trips to preempt the run).
    pub fn stop_token(&self) -> &Arc<StopToken> {
        &self.stop
    }

    pub fn target(&self) -> Option<i64> {
        self.target
    }

    /// Checked by solvers once per sweep: `true` when the run should
    /// return its best-so-far incumbent now — the token tripped (or the
    /// upstream token tripped; its cause is forwarded first so
    /// [`SolveCtl::cause`] reports it), or the incumbent already meets
    /// the target energy.
    pub fn should_stop(&self, best: i64) -> bool {
        if let Some(up) = &self.upstream {
            if let Some(cause) = up.get() {
                self.stop.trip(cause);
            }
        }
        if self.stop.is_stopped() {
            return true;
        }
        matches!(self.target, Some(t) if best <= t)
    }

    /// Why the run was preempted (`None` = ran to completion or stopped
    /// on its own target).
    pub fn cause(&self) -> Option<StopCause> {
        self.stop.get()
    }
}

/// A Table II/III comparator.
///
/// `Send + Sync` so harnesses can share one solver across the replica
/// pool's workers (every implementor is plain configuration data; all
/// run state lives in `solve_ctl`'s locals).
pub trait Solver: Send + Sync {
    /// Short name as used in the paper's tables (e.g. "Neal", "SFG").
    fn name(&self) -> &'static str;

    /// Minimize `model` within `budget`, deterministically in `seed`.
    fn solve(&self, model: &IsingModel, budget: Budget, seed: u64) -> SolveResult {
        self.solve_ctl(model, budget, seed, &SolveCtl::free())
    }

    /// [`Solver::solve`] under cooperative control: implementations
    /// check `ctl.should_stop(best)` at sweep granularity and return
    /// the best-so-far incumbent (a valid partial [`SolveResult`])
    /// when preempted. An unpreempted run is bit-identical to
    /// [`Solver::solve`].
    fn solve_ctl(
        &self,
        model: &IsingModel,
        budget: Budget,
        seed: u64,
        ctl: &SolveCtl,
    ) -> SolveResult;
}

/// Incrementally maintained chain state shared by the local-update
/// baselines: spins, local fields and energy, with Θ(N) flip cost.
pub struct ChainState {
    pub spins: SpinVec,
    pub u: Vec<i64>,
    pub energy: i64,
}

impl ChainState {
    pub fn new(model: &IsingModel, spins: SpinVec) -> Self {
        let u = model.local_fields(&spins);
        let energy = model.energy(&spins);
        Self { spins, u, energy }
    }

    /// ΔE of flipping spin `i` under the current state.
    #[inline(always)]
    pub fn delta_e(&self, i: usize) -> i64 {
        IsingModel::delta_e(self.spins.get(i), self.u[i])
    }

    /// Flip spin `i`, updating fields and energy (Eq. 12).
    #[inline(always)]
    pub fn flip(&mut self, model: &IsingModel, i: usize) {
        let de = self.delta_e(i);
        let s_old = self.spins.flip(i);
        self.energy += de;
        let factor = 2 * s_old as i64;
        model.j_row(i).fold_delta(factor, &mut self.u);
    }
}

/// Track the best configuration seen.
pub struct Best {
    pub energy: i64,
    pub spins: SpinVec,
}

impl Best {
    pub fn new(state: &ChainState) -> Self {
        Self { energy: state.energy, spins: state.spins.clone() }
    }

    #[inline(always)]
    pub fn observe(&mut self, state: &ChainState) {
        if state.energy < self.energy {
            self.energy = state.energy;
            self.spins = state.spins.clone();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::StatelessRng;
    use crate::testutil::gen;

    #[test]
    fn chain_state_flip_consistency() {
        let rng = StatelessRng::new(77);
        let m = gen::model(&rng, 30, 5);
        let mut st = ChainState::new(&m, gen::spins(&rng, 30));
        for i in [3usize, 17, 3, 29, 0] {
            st.flip(&m, i);
        }
        assert_eq!(st.energy, m.energy(&st.spins));
        assert_eq!(st.u, m.local_fields(&st.spins));
    }

    #[test]
    fn budget_attempts() {
        assert_eq!(Budget::sweeps(10).attempts(100), 1000);
    }
}
