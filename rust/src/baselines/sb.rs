//! Simulated bifurcation (SB [21], Goto et al.) — the FPGA comparator of
//! Table III.
//!
//! Ballistic SB (bSB): each spin carries a continuous position `x_i` and
//! momentum `y_i` evolving under the adiabatic Hamiltonian
//!
//! `ẏ_i = −(a(t) − a0)·x_i + c0·Σ_j J̃_ij x_j`,  `ẋ_i = a0·y_i`,
//!
//! with the pump `a(t)` ramped 0 → a0; positions are clamped to
//! `|x| ≤ 1` with inelastic walls (the "ballistic" variant that avoids
//! error accumulation). The readout is `s_i = sign(x_i)`. Note the sign
//! convention: the paper's Hamiltonian (Eq. 1) is `−Σ J s s`, so the
//! coupling drive uses `+J`.

use super::common::{Budget, SolveCtl, SolveResult, Solver};
use crate::ising::{IsingModel, SpinVec};
use crate::rng::{salt, StatelessRng};

/// Ballistic simulated bifurcation.
pub struct SimulatedBifurcation {
    pub dt: f64,
    pub a0: f64,
}

impl Default for SimulatedBifurcation {
    fn default() -> Self {
        Self { dt: 0.5, a0: 1.0 }
    }
}

impl Solver for SimulatedBifurcation {
    fn name(&self) -> &'static str {
        "SB"
    }

    fn solve_ctl(&self, model: &IsingModel, budget: Budget, seed: u64, ctl: &SolveCtl) -> SolveResult {
        let start = std::time::Instant::now();
        let n = model.len();
        let rng = StatelessRng::new(seed);
        // c0 scaling per Goto et al.: 0.5 / (sqrt(N) * σ_J).
        let mut sq = 0f64;
        let mut cnt = 0usize;
        for i in 0..n {
            for v in model.j_row(i).iter() {
                if v != 0 {
                    sq += (v as f64) * (v as f64);
                    cnt += 1;
                }
            }
        }
        let sigma = if cnt == 0 { 1.0 } else { (sq / cnt as f64).sqrt() };
        let c0 = 0.5 / ((n as f64).sqrt() * sigma);
        let mut x: Vec<f64> =
            (0..n).map(|i| 0.02 * (rng.unit_f64(50, i as u64, salt::BASELINE) - 0.5)).collect();
        let mut y: Vec<f64> =
            (0..n).map(|i| 0.02 * (rng.unit_f64(51, i as u64, salt::BASELINE) - 0.5)).collect();
        // One SB step costs ~1 sweep of local-field work; budget sweeps
        // map 1:1 to SB time steps.
        let steps = budget.sweeps.max(1);
        let mut attempts = 0u64;
        // Observe the initial readout so a preempted run still reports a
        // consistent (energy, spins) pair.
        let mut best_spins = readout(&x);
        let mut best_energy = model.energy(&best_spins);
        let check_stride = (steps / 32).max(1);
        for step in 0..steps {
            if ctl.should_stop(best_energy) {
                break;
            }
            let a = self.a0 * step as f64 / steps as f64;
            // y update with coupling drive (dense mat-vec).
            for i in 0..n {
                attempts += 1;
                let mut drive = 0f64;
                for (k, jv) in model.j_row(i).iter().enumerate() {
                    if jv != 0 {
                        drive += jv as f64 * x[k];
                    }
                }
                drive += model.h(i) as f64;
                y[i] += ((-(self.a0 - a)) * x[i] + c0 * drive) * self.dt;
            }
            // x update + inelastic walls.
            for i in 0..n {
                x[i] += self.a0 * y[i] * self.dt;
                if x[i].abs() > 1.0 {
                    x[i] = x[i].signum();
                    y[i] = 0.0;
                }
            }
            if step % check_stride == 0 || step + 1 == steps {
                let s = readout(&x);
                let e = model.energy(&s);
                if e < best_energy {
                    best_energy = e;
                    best_spins = s;
                }
            }
        }
        SolveResult { best_energy, best_spins, attempts, wall: start.elapsed() }
    }
}

fn readout(x: &[f64]) -> SpinVec {
    SpinVec::from_spins(&x.iter().map(|&v| if v >= 0.0 { 1i8 } else { -1 }).collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;

    #[test]
    fn sb_bifurcates_to_low_energy() {
        let rng = StatelessRng::new(4);
        let p = MaxCut::new(generators::erdos_renyi(64, 400, &[-1, 1], &rng));
        let r = SimulatedBifurcation::default().solve(p.model(), Budget::sweeps(400), 9);
        assert_eq!(r.best_energy, p.model().energy(&r.best_spins));
        assert!(r.best_energy < -80, "SB best {} too weak", r.best_energy);
    }

    #[test]
    fn ferromagnet_aligns() {
        let mut m = IsingModel::zeros(8);
        for i in 0..8u32 {
            for k in (i + 1)..8 {
                m.set_j(i as usize, k as usize, 1);
            }
        }
        let r = SimulatedBifurcation::default().solve(&m, Budget::sweeps(300), 2);
        assert_eq!(r.best_energy, -(8 * 7 / 2)); // all aligned
    }
}
