//! Checkerboard (two-colour) synchronous updates on bipartite topologies
//! — the §III-B mitigation [24] for the oscillation/detailed-balance
//! problems of naive all-spin updates.
//!
//! Spins are 2-coloured so no two adjacent spins share a colour; each
//! half-step updates one colour class synchronously. Because updated
//! spins never interact directly, the joint update factorizes into
//! independent single-site Glauber updates with *correct* conditional
//! distributions — detailed balance survives, unlike Eq. 4/5.
//! On non-bipartite graphs the constructor falls back to a greedy
//! colouring and more colour classes.

use super::common::{Best, Budget, ChainState, SolveCtl, SolveResult, Solver};
use crate::engine::lut::PwlLogistic;
use crate::ising::{IsingModel, SpinVec};
use crate::rng::{salt, StatelessRng};

/// Synchronous colour-class Glauber annealer.
pub struct Checkerboard {
    pub t0: f64,
    pub t1: f64,
}

impl Default for Checkerboard {
    fn default() -> Self {
        Self { t0: 8.0, t1: 0.05 }
    }
}

/// Greedy graph colouring over the coupling structure.
pub fn colour_classes(model: &IsingModel) -> Vec<Vec<usize>> {
    let n = model.len();
    let mut colour = vec![usize::MAX; n];
    let mut n_colours = 0;
    for i in 0..n {
        let mut used = vec![false; n_colours];
        for k in 0..n {
            if model.j(i, k) != 0 && colour[k] != usize::MAX {
                if colour[k] < used.len() {
                    used[colour[k]] = true;
                }
            }
        }
        let c = used.iter().position(|&u| !u).unwrap_or(n_colours);
        if c == n_colours {
            n_colours += 1;
        }
        colour[i] = c;
    }
    let mut classes = vec![Vec::new(); n_colours];
    for (i, &c) in colour.iter().enumerate() {
        classes[c].push(i);
    }
    classes
}

impl Solver for Checkerboard {
    fn name(&self) -> &'static str {
        "Checker"
    }

    fn solve_ctl(&self, model: &IsingModel, budget: Budget, seed: u64, ctl: &SolveCtl) -> SolveResult {
        let start = std::time::Instant::now();
        let n = model.len();
        let rng = StatelessRng::new(seed);
        let lut = PwlLogistic::default();
        let classes = colour_classes(model);
        let mut st = ChainState::new(model, SpinVec::random(n, &rng));
        let mut best = Best::new(&st);
        let iters = budget.sweeps.max(1);
        let mut attempts = 0u64;
        for it in 0..iters {
            if ctl.should_stop(best.energy) {
                break;
            }
            let frac = if iters == 1 { 1.0 } else { it as f64 / (iters - 1) as f64 };
            let temp = self.t0 * (self.t1 / self.t0).powf(frac);
            for (ci, class) in classes.iter().enumerate() {
                // All spins in a class are mutually non-interacting:
                // their flips commute, so a synchronous commit is an
                // exact product of single-site Glauber kernels.
                let decisions: Vec<usize> = class
                    .iter()
                    .copied()
                    .filter(|&i| {
                        attempts += 1;
                        let p = lut.flip_prob_q16(st.delta_e(i), temp);
                        let r = rng.u32(it, (ci as u64) << 32 | i as u64, salt::BASELINE) >> 16;
                        r < p
                    })
                    .collect();
                for i in decisions {
                    st.flip(model, i);
                }
            }
            best.observe(&st);
        }
        SolveResult { best_energy: best.energy, best_spins: best.spins, attempts, wall: start.elapsed() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;

    #[test]
    fn colouring_is_proper() {
        let rng = StatelessRng::new(3);
        let g = generators::erdos_renyi(40, 100, &[1], &rng);
        let p = MaxCut::new(g);
        let classes = colour_classes(p.model());
        for class in &classes {
            for (a, &i) in class.iter().enumerate() {
                for &j in &class[a + 1..] {
                    assert_eq!(p.model().j(i, j), 0, "same-class spins {i},{j} interact");
                }
            }
        }
        let total: usize = classes.iter().map(|c| c.len()).sum();
        assert_eq!(total, 40);
    }

    #[test]
    fn torus_is_two_colourable_and_anneals() {
        let rng = StatelessRng::new(5);
        let g = generators::torus(8, 8, &[1], &rng); // even torus = bipartite
        let p = MaxCut::new(g);
        let classes = colour_classes(p.model());
        assert_eq!(classes.len(), 2, "even torus must 2-colour (checkerboard)");
        let r = Checkerboard::default().solve(p.model(), Budget::sweeps(300), 7);
        assert_eq!(r.best_energy, p.model().energy(&r.best_spins));
        // All-positive couplings → antiferro Max-Cut on bipartite torus:
        // the optimum cuts every edge (cut = 128, energy = -128).
        assert_eq!(r.best_energy, -128, "checkerboard must solve the bipartite torus exactly");
    }

    #[test]
    fn no_oscillation_on_antiferromagnet() {
        // The §III-B killer for naive sync updates; checkerboard is immune.
        let mut m = IsingModel::zeros(2);
        m.set_j(0, 1, -1);
        let r = Checkerboard::default().solve(&m, Budget::sweeps(100), 1);
        assert_eq!(r.best_energy, -1);
    }
}
