//! Tabu search over single-spin moves — the "Tabu" column of Table II.
//!
//! Classic best-improvement tabu: each iteration flips the spin with the
//! lowest ΔE among non-tabu spins (aspiration: a tabu move is allowed if
//! it would beat the best energy seen), then makes it tabu for `tenure`
//! iterations.

use super::common::{Best, Budget, ChainState, SolveCtl, SolveResult, Solver};
use crate::ising::{IsingModel, SpinVec};
use crate::rng::StatelessRng;

/// Single-flip tabu search.
pub struct Tabu {
    /// Tabu tenure in iterations; 0 = auto (`max(10, N/10)`).
    pub tenure: u64,
}

impl Default for Tabu {
    fn default() -> Self {
        Self { tenure: 0 }
    }
}

impl Solver for Tabu {
    fn name(&self) -> &'static str {
        "Tabu"
    }

    fn solve_ctl(&self, model: &IsingModel, budget: Budget, seed: u64, ctl: &SolveCtl) -> SolveResult {
        let start = std::time::Instant::now();
        let n = model.len();
        let tenure = if self.tenure == 0 { (n as u64 / 10).max(10) } else { self.tenure };
        let rng = StatelessRng::new(seed);
        let mut st = ChainState::new(model, SpinVec::random(n, &rng));
        let mut best = Best::new(&st);
        // expire[i] = first iteration at which flipping i is allowed again.
        let mut expire = vec![0u64; n];
        let total = budget.attempts(n) / n as u64; // tabu evaluates all N per move
        let mut attempts = 0u64;
        for it in 0..total.max(1) {
            if ctl.should_stop(best.energy) {
                break;
            }
            // Best admissible move.
            let mut chosen: Option<(usize, i64)> = None;
            for i in 0..n {
                attempts += 1;
                let de = st.delta_e(i);
                let tabu = expire[i] > it;
                let aspirates = st.energy + de < best.energy;
                if tabu && !aspirates {
                    continue;
                }
                match chosen {
                    Some((_, b)) if de >= b => {}
                    _ => chosen = Some((i, de)),
                }
            }
            let Some((i, _)) = chosen else { break };
            st.flip(model, i);
            expire[i] = it + tenure;
            best.observe(&st);
        }
        SolveResult { best_energy: best.energy, best_spins: best.spins, attempts, wall: start.elapsed() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;

    #[test]
    fn tabu_escapes_local_minima() {
        let rng = StatelessRng::new(3);
        let p = MaxCut::new(generators::erdos_renyi(48, 220, &[-1, 1], &rng));
        let r = Tabu::default().solve(p.model(), Budget::sweeps(300), 5);
        assert_eq!(r.best_energy, p.model().energy(&r.best_spins));
        // Must beat pure greedy descent (which stalls at the first local
        // optimum) — compare against a short greedy run.
        let g = super::super::reaim::ReAim::sfg().solve(p.model(), Budget::sweeps(300), 5);
        assert!(r.best_energy <= g.best_energy, "tabu {} vs greedy {}", r.best_energy, g.best_energy);
    }

    #[test]
    fn tenure_blocks_immediate_reversal() {
        // On a 2-spin ferromagnet, after tabu flips one spin it must not
        // flip it straight back.
        let mut m = IsingModel::zeros(2);
        m.set_j(0, 1, 1);
        let r = Tabu { tenure: 5 }.solve(&m, Budget::sweeps(20), 1);
        assert_eq!(r.best_energy, -1); // aligned ground state
    }
}
