//! Coherent Ising machine (CIM [28]) — mean-field simulation of the
//! optical comparator of Table III (DESIGN.md §3 substitution: we cannot
//! run a fiber DOPO network, so we integrate the standard mean-field CIM
//! amplitude equations).
//!
//! Each spin is an optical-parametric-oscillator amplitude `x_i`:
//!
//! `ẋ_i = (p(t) − 1 − x_i²)·x_i + ε·(Σ_j J_ij x_j + h_i) + σ·ξ`
//!
//! with the pump `p(t)` ramped through threshold (0 → p_max) and
//! injection noise ξ. Readout is `s_i = sign(x_i)`. Gradual pump ramping
//! reproduces the bifurcation-based search the optics performs.

use super::common::{Budget, SolveCtl, SolveResult, Solver};
use crate::ising::{IsingModel, SpinVec};
use crate::rng::{salt, StatelessRng};

/// Mean-field CIM integrator.
pub struct Cim {
    pub dt: f64,
    pub p_max: f64,
    pub noise: f64,
}

impl Default for Cim {
    fn default() -> Self {
        Self { dt: 0.05, p_max: 2.0, noise: 0.05 }
    }
}

impl Solver for Cim {
    fn name(&self) -> &'static str {
        "CIM"
    }

    fn solve_ctl(&self, model: &IsingModel, budget: Budget, seed: u64, ctl: &SolveCtl) -> SolveResult {
        let start = std::time::Instant::now();
        let n = model.len();
        let rng = StatelessRng::new(seed);
        // Coupling normalization as in mean-field CIM studies.
        let mut max_row = 1f64;
        for i in 0..n {
            let s: i64 = model.j_row(i).iter().map(|v| v.unsigned_abs() as i64).sum();
            max_row = max_row.max(s as f64);
        }
        let eps = 0.5 / max_row;
        let mut x: Vec<f64> =
            (0..n).map(|i| 0.01 * (rng.unit_f64(60, i as u64, salt::BASELINE) - 0.5)).collect();
        let steps = budget.sweeps.max(1);
        let mut attempts = 0u64;
        // Observe the initial readout so a preempted run still reports a
        // consistent (energy, spins) pair.
        let mut best_spins = readout(&x);
        let mut best_energy = model.energy(&best_spins);
        let check_stride = (steps / 32).max(1);
        for step in 0..steps {
            if ctl.should_stop(best_energy) {
                break;
            }
            let pump = self.p_max * step as f64 / steps as f64;
            for i in 0..n {
                attempts += 1;
                let mut inj = model.h(i) as f64;
                for (k, jv) in model.j_row(i).iter().enumerate() {
                    if jv != 0 {
                        inj += jv as f64 * x[k];
                    }
                }
                // Box–Muller-free noise: two uniform draws, triangular
                // approximation is adequate for the injection term.
                let u1 = rng.unit_f64(step, (i as u64) << 1, salt::BASELINE);
                let u2 = rng.unit_f64(step, ((i as u64) << 1) | 1, salt::BASELINE);
                let xi = (u1 + u2) - 1.0;
                let g = (pump - 1.0 - x[i] * x[i]) * x[i] + eps * inj + self.noise * xi;
                x[i] += g * self.dt;
                // Amplitude clamp (saturation of the physical system).
                x[i] = x[i].clamp(-1.5, 1.5);
            }
            if step % check_stride == 0 || step + 1 == steps {
                let s = readout(&x);
                let e = model.energy(&s);
                if e < best_energy {
                    best_energy = e;
                    best_spins = s;
                }
            }
        }
        SolveResult { best_energy, best_spins, attempts, wall: start.elapsed() }
    }
}

fn readout(x: &[f64]) -> SpinVec {
    SpinVec::from_spins(&x.iter().map(|&v| if v >= 0.0 { 1i8 } else { -1 }).collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators;
    use crate::problems::MaxCut;

    #[test]
    fn cim_finds_low_energy() {
        let rng = StatelessRng::new(8);
        let p = MaxCut::new(generators::erdos_renyi(48, 220, &[-1, 1], &rng));
        let r = Cim::default().solve(p.model(), Budget::sweeps(600), 15);
        assert_eq!(r.best_energy, p.model().energy(&r.best_spins));
        assert!(r.best_energy < -40, "CIM best {} too weak", r.best_energy);
    }

    #[test]
    fn ferromagnet_orders_below_threshold() {
        let mut m = IsingModel::zeros(6);
        for i in 0..6u32 {
            for k in (i + 1)..6 {
                m.set_j(i as usize, k as usize, 1);
            }
        }
        let r = Cim::default().solve(&m, Budget::sweeps(400), 1);
        assert_eq!(r.best_energy, -15);
    }
}
