//! Minimal dependency-free CLI argument parsing (the offline environment
//! has no `clap`; this covers the `snowball` binary's needs).
//!
//! Grammar: `snowball <command> [--key value]... [--flag]... [positional]`.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: std::collections::HashSet<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> anyhow::Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        // A leading option token means there is no subcommand (the
        // examples parse straight options).
        if it.peek().is_some_and(|a| !a.starts_with("--")) {
            out.command = it.next().unwrap();
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // `--key value` unless the next token is another option
                // or absent → boolean flag.
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.options.insert(key.to_string(), v);
                    }
                    _ => {
                        out.flags.insert(key.to_string());
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn from_env() -> anyhow::Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default.
    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    /// Boolean flag (present or `--key true`).
    pub fn flag(&self, key: &str) -> bool {
        self.flags.contains(key) || self.get(key).is_some_and(|v| v == "true" || v == "1")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|v| v.to_string())).unwrap()
    }

    #[test]
    fn commands_options_flags_positionals() {
        // NB: `--key value` greedily consumes the next non-option token,
        // so bare flags go last (or use `--flag --next-option` forms).
        let a = parse(&["solve", "G6", "--steps", "100", "--mode", "rwa", "--verbose"]);
        assert_eq!(a.command, "solve");
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("mode"), Some("rwa"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["G6"]);
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&["x", "--n", "42"]);
        assert_eq!(a.get_parse_or("n", 7u64).unwrap(), 42);
        assert_eq!(a.get_parse_or("m", 7u64).unwrap(), 7);
        assert!(a.get_parse_or("n", 1.5f64).is_ok());
    }

    #[test]
    fn bad_typed_value_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_parse_or("n", 7u64).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["x", "--fast"]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn leading_option_means_no_command() {
        let a = parse(&["--instance", "G18", "--sweeps", "10"]);
        assert_eq!(a.command, "");
        assert_eq!(a.get("instance"), Some("G18"));
        assert_eq!(a.get("sweeps"), Some("10"));
    }
}
