//! Repo task runner. Two subcommands:
//!
//! * `cargo run -p xtask -- lint-safety` — the unsafe-code and atomics
//!   policy gate (CI job `lint-safety`; rationale in
//!   `docs/ARCHITECTURE.md` § Concurrency correctness);
//! * `cargo run -p xtask -- kick-tires [--smoke|--full]` — regenerate
//!   every `BENCH_*.json` report by driving the microbench suites in
//!   sequence (engine, shards, registry, load, portfolio, precision,
//!   locality).
//!   `--smoke` (the default) uses the quick profiles; `--full` runs the
//!   real campaign.
//!
//! # The lint-safety gate
//!
//! The compiler already enforces the hard boundary (`#![deny(unsafe_code)]`
//! at the crate root, re-escalated to `forbid` on every non-audited
//! module). This scanner enforces what lints cannot express:
//!
//! * **R1** — `unsafe` (and `allow(unsafe_code)`) may appear only in the
//!   five audited allowlist files. Growing the allowlist is a reviewed
//!   decision: it requires editing this file.
//! * **R2** — inside allowlisted files, every `unsafe` operation must
//!   carry a `SAFETY:` comment (or a `# Safety` doc section for
//!   `unsafe fn`) within the preceding lines.
//! * **R3** — `Ordering::SeqCst` is banned everywhere. SeqCst is how
//!   lock-free code hides a fence it cannot explain; an algorithm that
//!   seems to need it needs a loom model first.
//! * **R4** — the literal path `std::sync::atomic` may appear only in
//!   `src/sync.rs` (the shim itself) and `src/coordinator/metrics.rs`
//!   (documented exception: `or_default()` needs `Default`, which
//!   loom's doubles don't implement). Everything else must import from
//!   `crate::sync::atomic` so it stays loom-checkable.
//! * **R5** — `Ordering::Relaxed` is restricted to audited files whose
//!   relaxed operations are single-owner index reads or commutative
//!   counter updates; new code gets Acquire/Release until a loom model
//!   argues otherwise.
//!
//! The checks are textual by design: zero dependencies, no syn/AST, so
//! the gate runs in CI before (and regardless of) any full build. The
//! scanner reads `rust/{src,tests,benches,examples}` only — its own
//! source (which must spell the banned tokens) is not scanned.

#![forbid(unsafe_code)]

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Files allowed to contain `unsafe` (with per-operation `SAFETY:`
/// comments — rule R2). Paths relative to `rust/`.
const UNSAFE_ALLOWLIST: &[&str] = &[
    "src/sync.rs",
    "src/engine/lut.rs",
    "src/engine/shard/affinity.rs",
    "src/engine/shard/mailbox.rs",
    "src/ising/store.rs",
];

/// Files allowed to name the literal path `std::sync::atomic` (rule R4).
const STD_ATOMIC_ALLOWLIST: &[&str] = &["src/sync.rs", "src/coordinator/metrics.rs"];

/// Files allowed to use `Ordering::Relaxed` (rule R5).
const RELAXED_ALLOWLIST: &[&str] = &[
    "src/sync.rs",
    "src/engine/shard/gate.rs",
    "src/engine/shard/mailbox.rs",
    "src/engine/shard/mod.rs",
    "src/coordinator/metrics.rs",
    "src/coordinator/mod.rs",
    "src/coordinator/scheduler.rs",
];

/// How far back (in lines) a `SAFETY:` / `# Safety` marker may sit from
/// the unsafe operation it justifies. Generous enough for a doc-comment
/// `# Safety` section above an `unsafe fn`'s attributes.
const SAFETY_WINDOW: usize = 10;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint-safety") => lint_safety(),
        Some("kick-tires") => kick_tires(args.get(1).map(String::as_str)),
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint-safety | kick-tires [--smoke|--full]");
            ExitCode::from(2)
        }
    }
}

/// `kick-tires`: drive every microbench suite so the `BENCH_*.json`
/// reports are regenerated in one command (what the CI bench lane and a
/// fresh checkout both want). Stops at the first failing suite.
fn kick_tires(profile: Option<&str>) -> ExitCode {
    let full = match profile {
        None | Some("--smoke") => false,
        Some("--full") => true,
        Some(other) => {
            eprintln!("kick-tires: unknown profile '{other}' (expected --smoke|--full)");
            return ExitCode::from(2);
        }
    };
    let rust_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../rust");
    let suites: &[&[&str]] = &[
        &[], // engine hot paths → BENCH_engine.json
        &["--shards"],
        &["--registry"],
        &["--load"],
        &["--portfolio"],
        &["--precision"],
        &["--locality"],
    ];
    for suite in suites {
        let mut cmd = std::process::Command::new("cargo");
        cmd.args(["bench", "--bench", "microbench", "--"]).args(*suite);
        if !full {
            // The engine suite has a dedicated smoke profile; the rest
            // use their quick profile.
            cmd.arg(if suite.is_empty() { "--smoke" } else { "--quick" });
        }
        cmd.current_dir(&rust_root);
        println!("kick-tires: microbench {}", if suite.is_empty() { "(engine)" } else { suite[0] });
        match cmd.status() {
            Ok(s) if s.success() => {}
            Ok(s) => {
                eprintln!("kick-tires: suite failed with {s}");
                return ExitCode::FAILURE;
            }
            Err(e) => {
                eprintln!("kick-tires: cannot spawn cargo: {e}");
                return ExitCode::from(2);
            }
        }
    }
    println!("kick-tires: all BENCH_*.json reports refreshed under rust/");
    ExitCode::SUCCESS
}

fn lint_safety() -> ExitCode {
    // CARGO_MANIFEST_DIR = <repo>/xtask, the crate root lives beside it.
    let rust_root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../rust");
    let mut files = Vec::new();
    for sub in ["src", "tests", "benches", "examples"] {
        collect_rs_files(&rust_root.join(sub), &mut files);
    }
    files.sort();
    if files.is_empty() {
        eprintln!("lint-safety: no .rs files found under {}", rust_root.display());
        return ExitCode::from(2);
    }
    let mut violations = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&rust_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("lint-safety: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        };
        check_file(&rel, &text, &mut violations);
    }
    if violations.is_empty() {
        println!("lint-safety: {} files clean", files.len());
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("lint-safety: {v}");
        }
        eprintln!("lint-safety: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return; // `examples/` etc. may legitimately not exist
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// Run every rule against one file, appending human-readable violations.
fn check_file(rel: &str, text: &str, violations: &mut Vec<String>) {
    let unsafe_ok = UNSAFE_ALLOWLIST.contains(&rel);
    let std_atomic_ok = STD_ATOMIC_ALLOWLIST.contains(&rel);
    let relaxed_ok = RELAXED_ALLOWLIST.contains(&rel);
    let lines: Vec<&str> = text.lines().collect();
    for (idx, raw) in lines.iter().enumerate() {
        let n = idx + 1;
        if is_comment(raw) {
            continue;
        }
        if is_attribute(raw) {
            // R1b: an attribute re-allowing unsafe outside the audited
            // set is exactly the bypass this gate exists to catch.
            if !unsafe_ok && raw.contains("allow(unsafe_code)") {
                violations.push(format!(
                    "{rel}:{n}: allow(unsafe_code) outside the audited allowlist \
                     (R1; the list lives in xtask/src/main.rs)"
                ));
            }
            continue;
        }
        let code = strip_trailing_comment(raw);
        if has_word(code, "unsafe") {
            if !unsafe_ok {
                violations.push(format!(
                    "{rel}:{n}: `unsafe` outside the audited allowlist \
                     (R1; the list lives in xtask/src/main.rs)"
                ));
            } else if !safety_marker_near(&lines, idx) {
                violations.push(format!(
                    "{rel}:{n}: unsafe operation without a `SAFETY:` comment \
                     within the preceding {SAFETY_WINDOW} lines (R2)"
                ));
            }
        }
        if has_word(code, "SeqCst") {
            violations.push(format!(
                "{rel}:{n}: Ordering::SeqCst is banned — justify the exact \
                 Acquire/Release pairing, with a loom model if novel (R3)"
            ));
        }
        if code.contains("std::sync::atomic") && !std_atomic_ok {
            violations.push(format!(
                "{rel}:{n}: literal std::sync::atomic — import from \
                 crate::sync::atomic so the code stays loom-checkable (R4)"
            ));
        }
        if code.contains("Ordering::Relaxed") && !relaxed_ok {
            violations.push(format!(
                "{rel}:{n}: Ordering::Relaxed outside the audited relaxed \
                 allowlist — start from Acquire/Release (R5)"
            ));
        }
    }
}

/// Is there a `SAFETY:` / `# Safety` marker on this line or within the
/// preceding window? (The same-line case covers `unsafe { ... } // SAFETY:`,
/// which rustfmt sometimes produces for short expressions.)
fn safety_marker_near(lines: &[&str], idx: usize) -> bool {
    let lo = idx.saturating_sub(SAFETY_WINDOW);
    lines[lo..=idx]
        .iter()
        .any(|l| l.contains("SAFETY:") || l.contains("# Safety"))
}

/// Line is entirely a comment (`//`, `///`, `//!`).
fn is_comment(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

/// Line is an attribute (`#[...]` / `#![...]`). One-line attributes
/// only — which is all rustfmt emits for the lint attributes we police.
fn is_attribute(line: &str) -> bool {
    let t = line.trim_start();
    t.starts_with("#[") || t.starts_with("#![")
}

/// Drop a trailing `//` comment so prose there can mention the policed
/// tokens. Naive about `//` inside string literals, which is fine for a
/// linter that only ever produces false *positives* loud enough to read.
fn strip_trailing_comment(line: &str) -> &str {
    match line.find("//") {
        Some(pos) => &line[..pos],
        None => line,
    }
}

/// `word` appears in `s` delimited by non-identifier characters — so
/// `unsafe` does not match `unsafe_code` and `SeqCst` does not match a
/// hypothetical `SeqCstLike` identifier.
fn has_word(s: &str, word: &str) -> bool {
    let is_ident = |c: char| c.is_alphanumeric() || c == '_';
    let mut start = 0;
    while let Some(pos) = s[start..].find(word) {
        let at = start + pos;
        let before_ok = s[..at].chars().next_back().is_none_or(|c| !is_ident(c));
        let after_ok = s[at + word.len()..].chars().next().is_none_or(|c| !is_ident(c));
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_matching_respects_identifier_boundaries() {
        assert!(has_word("let x = unsafe { *p };", "unsafe"));
        assert!(has_word("unsafe impl Send for T {}", "unsafe"));
        assert!(!has_word("#![deny(unsafe_code)]", "unsafe"));
        assert!(!has_word("deny(unsafe_op_in_unsafe_fn)", "unsafe"));
        assert!(has_word("a.load(Ordering::SeqCst)", "SeqCst"));
        assert!(!has_word("SeqCstLike::thing()", "SeqCst"));
    }

    #[test]
    fn comment_and_attribute_lines_are_classified() {
        assert!(is_comment("  // unsafe is discussed here"));
        assert!(is_comment("//! module docs mention SeqCst"));
        assert!(is_attribute("#[forbid(unsafe_code)]"));
        assert!(is_attribute("    #![allow(unsafe_code)]"));
        assert!(!is_attribute("let x = 1; // #[not_an_attr]"));
        assert_eq!(strip_trailing_comment("foo(); // SeqCst prose"), "foo(); ");
    }

    fn run(rel: &str, text: &str) -> Vec<String> {
        let mut v = Vec::new();
        check_file(rel, text, &mut v);
        v
    }

    #[test]
    fn r1_flags_unsafe_outside_allowlist_only() {
        let v = run("src/engine/pool.rs", "fn f() { unsafe { danger() } }\n");
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("R1"));
        // Same code in an allowlisted file trips R2 instead (no SAFETY).
        let v = run("src/engine/lut.rs", "fn f() { unsafe { danger() } }\n");
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("R2"));
    }

    #[test]
    fn r1_flags_sneaky_allow_attribute() {
        let v = run("src/graph.rs", "#![allow(unsafe_code)]\n");
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("R1"));
        // The audited files may allow — that is the whole mechanism.
        assert!(run("src/sync.rs", "#![allow(unsafe_code)]\n").is_empty());
    }

    #[test]
    fn r2_accepts_nearby_safety_comment_and_doc_section() {
        let ok = "// SAFETY: p is valid for the closure's lifetime.\n\
                  let v = cell.with(|p| unsafe { *p });\n";
        assert!(run("src/engine/shard/mailbox.rs", ok).is_empty());
        let doc = "/// # Safety\n/// Caller checked AVX2.\n\
                   #[target_feature(enable = \"avx2\")]\nunsafe fn kernel() {}\n";
        assert!(run("src/engine/lut.rs", doc).is_empty());
        let gap = "\n".repeat(SAFETY_WINDOW + 1);
        let far = format!("// SAFETY: too far away.\n{gap}unsafe fn f() {{}}\n");
        assert_eq!(run("src/engine/lut.rs", &far).len(), 1);
    }

    #[test]
    fn r3_bans_seqcst_in_code_but_not_prose() {
        let v = run("src/engine/select.rs", "a.load(Ordering::SeqCst);\n");
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("R3"));
        assert!(run("src/engine/select.rs", "// SeqCst is banned, see xtask\n").is_empty());
        // Banned even in the unsafe/relaxed allowlists — no file may use it.
        assert_eq!(run("src/sync.rs", "a.load(Ordering::SeqCst);\n").len(), 1);
    }

    #[test]
    fn r4_and_r5_respect_their_allowlists() {
        let v = run("src/engine/pool.rs", "use std::sync::atomic::AtomicU64;\n");
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("R4"));
        let metrics = "use std::sync::atomic::AtomicU64;\n";
        assert!(run("src/coordinator/metrics.rs", metrics).is_empty());
        let v = run("src/engine/pool.rs", "a.load(Ordering::Relaxed);\n");
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("R5"));
        assert!(run("src/engine/shard/gate.rs", "a.load(Ordering::Relaxed);\n").is_empty());
    }
}
