"""Stateless counter-based RNG — jnp mirror of ``rust/src/rng.rs``.

The Rust engine and the AOT XLA chunk must draw *identical* randomness so
their trajectories are bit-identical (the parity property asserted by
``rust/tests/xla_parity.rs`` and ``python/tests/test_rng_parity.py``).
Everything here is a pure function of (seed, stage, iter, salt), exactly
like the hardware's stateless generator (paper §IV-B3d).

All ops are uint64; ``jax_enable_x64`` must be on (aot.py sets it).
"""

import jax.numpy as jnp

# Purpose salts (rust/src/rng.rs::salt).
SALT_SITE = 0x01
SALT_ACCEPT = 0x02
SALT_ROULETTE = 0x03
SALT_UNIFORMIZE = 0x04
SALT_INIT = 0x05

_GAMMA = 0x9E3779B97F4A7C15
_MIX1 = 0xBF58476D1CE4E5B9
_MIX2 = 0x94D049BB133111EB
_K2 = 0xC2B2AE3D27D4EB4F
_K3 = 0x165667B19E3779F9

_U64 = jnp.uint64


def u64(x):
    """Cast to uint64 (wrapping semantics in XLA integer arithmetic)."""
    return jnp.asarray(x, dtype=_U64)


def mix64(z):
    """splitmix64 finalizer (rust ``mix64``)."""
    z = u64(z) + u64(_GAMMA)
    z = (z ^ (z >> u64(30))) * u64(_MIX1)
    z = (z ^ (z >> u64(27))) * u64(_MIX2)
    return z ^ (z >> u64(31))


def _rotr32(x):
    return (x >> u64(32)) | (x << u64(32))


def squares32(ctr, key):
    """Widynski squares RNG, 4 rounds (rust ``squares32``); returns uint32."""
    ctr, key = u64(ctr), u64(key)
    x = ctr * key
    y = x
    z = y + key
    x = _rotr32(x * x + y)
    x = _rotr32(x * x + z)
    x = _rotr32(x * x + y)
    return ((x * x + z) >> u64(32)).astype(jnp.uint32)


def counter(stage, iter_, salt):
    """Combine call indices into the squares counter (rust ``counter``)."""
    return mix64(u64(stage) * u64(_GAMMA) + u64(iter_) * u64(_K2) + u64(salt) * u64(_K3))


def rng_u32(seed, stage, iter_, salt):
    """Uniform 32-bit draw (rust ``StatelessRng::u32``)."""
    return squares32(counter(stage, iter_, salt), mix64(seed) | u64(1))


def rng_u64(seed, stage, iter_, salt):
    """Uniform 64-bit draw (two 32-bit lanes, rust ``StatelessRng::u64``)."""
    lo = rng_u32(seed, stage, iter_, salt).astype(_U64)
    hi = rng_u32(seed, stage, iter_, u64(salt) ^ u64(0x8000000000000000)).astype(_U64)
    return (hi << u64(32)) | lo


def rng_below(seed, stage, iter_, salt, n):
    """Uniform integer in {0..n-1} via Eq. 22 (rust ``below``)."""
    draw = rng_u32(seed, stage, iter_, salt).astype(_U64)
    return ((draw * u64(n)) >> u64(32)).astype(jnp.uint32)


def mulhi64(a, b):
    """High 64 bits of a 64×64 product (rust ``(a as u128 * b) >> 64``)."""
    a, b = u64(a), u64(b)
    mask = u64(0xFFFFFFFF)
    ah, al = a >> u64(32), a & mask
    bh, bl = b >> u64(32), b & mask
    lo = al * bl
    m1 = ah * bl
    m2 = al * bh
    carry = ((lo >> u64(32)) + (m1 & mask) + (m2 & mask)) >> u64(32)
    return ah * bh + (m1 >> u64(32)) + (m2 >> u64(32)) + carry


def draw_below_u64(seed, stage, bound):
    """Uniform in [0, bound) by 64-bit fixed-point multiply
    (rust ``SnowballEngine::draw_below``, salt ROULETTE, iter 0)."""
    raw = rng_u64(seed, stage, 0, SALT_ROULETTE)
    return mulhi64(raw, bound)


def child_seed(seed, index):
    """Decorrelated child stream (rust ``StatelessRng::child``)."""
    return mix64(u64(seed) ^ mix64(u64(index) ^ u64(_K2)))
