"""Pure-numpy correctness oracles for the Pallas kernels and the chunk step.

These deliberately avoid ``pallas_call`` and the jnp helper code paths,
so a bug in the kernels cannot hide in a shared implementation:
``flip_probs_ref`` re-derives the PWL from the table with python floats;
``field_init_ref`` is an exact integer mat-vec; ``roulette_select_ref``
mirrors the Rust prefix scan; ``chunk_step_ref`` is the per-step oracle
for the full anneal chunk.
"""

import numpy as np

from . import pwl, rng_py


def flip_probs_ref(s, u, temp):
    """Q16 flip probabilities, straight-line implementation."""
    s = np.asarray(s, dtype=np.float64)
    u = np.asarray(u, dtype=np.float64)
    t = float(np.asarray(temp).reshape(-1)[0])
    de = 2.0 * s * u
    out = np.zeros(s.shape[0], dtype=np.uint32)
    inv_t = 1.0 / t if t > 0.0 else 0.0
    tf = pwl.TABLE_F64
    for i, z_num in enumerate(de):
        if t <= 0.0:
            out[i] = pwl.ONE_Q16 if z_num < 0 else (pwl.ONE_Q16 // 2 if z_num == 0 else 0)
            continue
        # Mirrors rust eval_q16: reciprocal multiply, clamp, padded lerp.
        z = z_num * inv_t
        pos = (z + pwl.Z_MAX) * pwl.INV_STEP
        pos = min(max(pos, 0.0), float(pwl.SEGMENTS))
        idx = int(pos)
        frac = pos - idx
        a = tf[idx]
        b = tf[idx + 1]
        out[i] = np.uint32(int(a + (b - a) * frac))
    return out


def field_init_ref(planes_signed, s):
    """Dense oracle: u = Σ_b 2^b (P_b @ s) in exact integer arithmetic."""
    planes = np.asarray(planes_signed)
    s64 = np.asarray(s, dtype=np.int64)
    b = planes.shape[0]
    acc = np.zeros(planes.shape[1], dtype=np.int64)
    for p in range(b):
        acc += (1 << p) * (planes[p].astype(np.int64) @ s64)
    return acc.astype(np.float64)


def roulette_select_ref(p_q16, r):
    """First index j with cum(j) > r (rust prefix scan)."""
    cum = np.cumsum(np.asarray(p_q16, dtype=np.uint64))
    j = int(np.searchsorted(cum, r, side="right"))
    return min(j, len(cum) - 1)


def encode_planes(j_matrix):
    """Integer coupling matrix → signed {−1,0,+1} planes (inputs for the
    field_init kernel; inverse of plane reconstruction, Eq. 13)."""
    j = np.asarray(j_matrix, dtype=np.int64)
    bmax = int(np.abs(j).max()) if j.size else 0
    planes_needed = max(1, int(bmax).bit_length())
    mag = np.abs(j)
    sign = np.sign(j)
    planes = np.stack(
        [((mag >> p) & 1) * sign for p in range(planes_needed)], axis=0
    ).astype(np.float32)
    return planes


def energy_ref(j_matrix, h, s):
    """H(s) = −½ sᵀJs − h·s (Eq. 1; J symmetric, zero diagonal)."""
    j = np.asarray(j_matrix, dtype=np.float64)
    s = np.asarray(s, dtype=np.float64)
    h = np.asarray(h, dtype=np.float64)
    return -0.5 * s @ j @ s - h @ s


def local_fields_ref(j_matrix, h, s):
    """u_i = h_i + Σ_j J_ij s_j."""
    j = np.asarray(j_matrix, dtype=np.float64)
    return np.asarray(h, dtype=np.float64) + j @ np.asarray(s, dtype=np.float64)


def chunk_step_ref(j_matrix, s, u, energy, temp, seed, stage):
    """One roulette step, python-int exact — the oracle for
    ``model.anneal_chunk``. Mirrors ``SnowballEngine::step_roulette``
    including the W == 0 random-scan fallback.

    Returns (s, u, energy, flipped_index | None).
    """
    n = len(s)
    p = flip_probs_ref(s, u, temp)
    w = int(p.sum(dtype=np.uint64))
    s = np.asarray(s, dtype=np.float64).copy()
    u = np.asarray(u, dtype=np.float64).copy()
    if w == 0:
        jsite = rng_py.below(seed, stage, 0, rng_py.SALT_SITE, n)
        pj = flip_probs_ref(s[jsite : jsite + 1], u[jsite : jsite + 1], temp)[0]
        r = rng_py.u32(seed, stage, 0, rng_py.SALT_ACCEPT) >> 16
        if r >= pj:
            return s, u, energy, None
        chosen = jsite
    else:
        r = rng_py.draw_below(seed, stage, w)
        chosen = roulette_select_ref(p, r)
    de = 2.0 * s[chosen] * u[chosen]
    s_old = s[chosen]
    s[chosen] = -s_old
    energy = energy + de
    u -= 2.0 * s_old * np.asarray(j_matrix, dtype=np.float64)[chosen]
    return s, u, energy, chosen


def anneal_chunk_ref(j_matrix, s, u, energy, temps, seed, step0):
    """Full-chunk oracle: iterate ``chunk_step_ref`` over the schedule."""
    trace = []
    for t, temp in enumerate(temps):
        s, u, energy, _ = chunk_step_ref(j_matrix, s, u, energy, temp, seed, step0 + t)
        trace.append(energy)
    return s, u, energy, np.asarray(trace, dtype=np.float64)
