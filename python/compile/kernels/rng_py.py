"""Pure-python-int mirror of the stateless RNG (exact uint64 semantics).

Used by the numpy oracles (``ref.py``) and the golden-vector parity tests
against both the jnp implementation (``rng_ref.py``) and the Rust one
(``rust/src/rng.rs``).
"""

M64 = (1 << 64) - 1

SALT_SITE = 0x01
SALT_ACCEPT = 0x02
SALT_ROULETTE = 0x03
SALT_UNIFORMIZE = 0x04
SALT_INIT = 0x05

_GAMMA = 0x9E3779B97F4A7C15
_MIX1 = 0xBF58476D1CE4E5B9
_MIX2 = 0x94D049BB133111EB
_K2 = 0xC2B2AE3D27D4EB4F
_K3 = 0x165667B19E3779F9


def mix64(z):
    z = (z + _GAMMA) & M64
    z = ((z ^ (z >> 30)) * _MIX1) & M64
    z = ((z ^ (z >> 27)) * _MIX2) & M64
    return z ^ (z >> 31)


def _rotr32(x):
    return ((x >> 32) | (x << 32)) & M64


def squares32(ctr, key):
    x = (ctr * key) & M64
    y = x
    z = (y + key) & M64
    x = _rotr32((x * x + y) & M64)
    x = _rotr32((x * x + z) & M64)
    x = _rotr32((x * x + y) & M64)
    return ((x * x + z) & M64) >> 32


def counter(stage, iter_, salt):
    return mix64((stage * _GAMMA + iter_ * _K2 + salt * _K3) & M64)


def u32(seed, stage, iter_, salt):
    return squares32(counter(stage, iter_, salt), mix64(seed) | 1)


def u64(seed, stage, iter_, salt):
    lo = u32(seed, stage, iter_, salt)
    hi = u32(seed, stage, iter_, salt ^ 0x8000000000000000)
    return (hi << 32) | lo


def below(seed, stage, iter_, salt, n):
    return (u32(seed, stage, iter_, salt) * n) >> 32


def unit_f32(seed, stage, iter_, salt):
    return (u32(seed, stage, iter_, salt) >> 8) * (1.0 / 16777216.0)


def draw_below(seed, stage, bound):
    """rust ``SnowballEngine::draw_below`` (128-bit multiply high)."""
    raw = u64(seed, stage, 0, SALT_ROULETTE)
    return (raw * bound) >> 64


def child_seed(seed, index):
    return mix64(seed ^ mix64(index ^ _K2))


def spin_words(seed, n_words):
    """rust ``SpinVec::random``: one u64 draw per word, stage 0, salt INIT."""
    return [u64(seed, 0, w, SALT_INIT) for w in range(n_words)]
