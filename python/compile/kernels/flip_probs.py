"""L1 Pallas kernel: per-spin Glauber flip probabilities (Q16).

The FPGA evaluates all N candidate flips in parallel lanes through the
piecewise-linear LUT (paper §IV-B3a/c). On a TPU-shaped machine the same
structure is a VPU-vectorized PWL over spin blocks held in VMEM; the
BlockSpec below expresses the lane blocking the hardware did with BRAM
port pairs (DESIGN.md §Hardware-Adaptation).

``interpret=True`` everywhere: real-TPU lowering emits Mosaic custom
calls the CPU PJRT plugin cannot run; interpret mode lowers to plain HLO
with identical numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import pwl

# Spin-lane block per grid step (the FPGA's eval_lanes analogue; a VPU
# lane multiple).
BLOCK = 256


def _kernel(s_ref, u_ref, temp_ref, table_ref, o_ref):
    """One block: ΔE = 2·s·u, then the PWL LUT at ΔE/T (Eqs. 24–25).

    The Q16 segment table arrives as an input (pallas kernels cannot
    capture array constants), shared across all grid steps.
    """
    s = s_ref[...].astype(jnp.float64)
    u = u_ref[...]
    temp = temp_ref[0]
    de = 2.0 * s * u
    o_ref[...] = pwl.flip_prob_q16_with_table(de, temp, table_ref[...])


@functools.partial(jax.jit, static_argnames=("block",))
def flip_probs_q16(s, u, temp, block=BLOCK):
    """Q16 flip probabilities for all spins.

    s:    f32[N] spins (±1)
    u:    f64[N] local fields (integer-valued)
    temp: f64[1] temperature
    →     u32[N]
    """
    n = s.shape[0]
    if n % block != 0:
        # Small instances: fall back to a single block.
        block = n
    grid = (n // block,)
    table = jnp.asarray(pwl.TABLE_F64)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((pwl.SEGMENTS + 2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=True,
    )(s, u, temp, table)
