"""L1 Pallas kernel: bit-plane local-field initialization.

FPGA → TPU adaptation of the Hamming-weight accumulator (Eqs. 14–16).
The FPGA streams 64-coupler words through popcount units; the MXU-shaped
equivalent is a plane-weighted mat-vec: with signed planes
``P_b = B⁺_b − B⁻_b ∈ {−1,0,1}``,

    u^(J) = Σ_b 2^b · (P_b @ s),

one (block × N) tile of each plane resident in VMEM per grid step — the
BlockSpec plays the role the row-major BRAM bursts did. Products are
exact in f32 (entries ±1, partial sums ≤ N < 2^24) and accumulated in
f64 across planes.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 64


def _kernel(planes_ref, weights_ref, s_ref, o_ref):
    """Accumulate Σ_b 2^b (P_b @ s) for one row block."""
    planes = planes_ref[...]  # [B, block, N] f32
    s = s_ref[...]  # [N] f32
    w = weights_ref[...]  # [B] f32 (2^b)
    # Per-plane mat-vec on the MXU; weighted f64 accumulation.
    prods = jnp.einsum("brn,n->br", planes, s, preferred_element_type=jnp.float32)
    acc = jnp.sum(prods.astype(jnp.float64) * w.astype(jnp.float64)[:, None], axis=0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("row_block",))
def field_init(planes_signed, s, row_block=ROW_BLOCK):
    """Coupler-induced local fields from signed bit-planes.

    planes_signed: f32[B, N, N] with entries in {−1, 0, +1}
    s:             f32[N] spins (±1)
    →              f64[N]  (u^(J) = Σ_j J_ij s_j)
    """
    b, n, _ = planes_signed.shape
    if n % row_block != 0:
        row_block = n
    weights = jnp.asarray([float(1 << p) for p in range(b)], dtype=jnp.float32)
    grid = (n // row_block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, row_block, n), lambda i: (0, i, 0)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((row_block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float64),
        interpret=True,
    )(planes_signed, weights, s)
