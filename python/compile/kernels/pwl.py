"""Piecewise-linear Glauber LUT — jnp mirror of ``rust/src/engine/lut.rs``.

The Q16 table and evaluation order are replicated operation-for-operation
in f64 so the XLA chunk and the Rust engine compute *identical* flip
probabilities (parity asserted by ``python/tests/test_pwl_parity.py`` and
``rust/tests/xla_parity.rs``).
"""

import math

import jax.numpy as jnp
import numpy as np

ONE_Q16 = 1 << 16
SEGMENTS = 256
Z_MAX = 16.0
_STEP = 2.0 * Z_MAX / SEGMENTS
INV_STEP = 1.0 / _STEP


def glauber_exact(z):
    """Exact Glauber flip probability 1/(1+e^z)."""
    return 1.0 / (1.0 + np.exp(z))


def build_table():
    """Q16 endpoint table, identical to ``PwlLogistic::new(256, 16.0)``."""
    zs = -Z_MAX + _STEP * np.arange(SEGMENTS + 1)
    vals = np.array(
        # Python round() is banker's; Rust f64::round() rounds half away
        # from zero — use floor(x+0.5) which matches for positive values.
        [math.floor(glauber_exact(z) * ONE_Q16 + 0.5) for z in zs],
        dtype=np.uint32,
    )
    return vals


TABLE = build_table()
# f64 view used inside lowered graphs, padded with a duplicated tail entry
# so idx+1 is always in range (mirrors rust `table_f64`). NB: the
# xla_extension 0.5.1 runtime that executes our AOT artifacts mis-executes
# HLO `gather` (returns index garbage — see DESIGN.md §AOT-constraints),
# so all table lookups below are one-hot contractions instead of
# `table[idx]`. On a real TPU that is also the natural MXU formulation of
# a small LUT.
TABLE_F64 = np.concatenate([TABLE.astype(np.float64), TABLE[-1:].astype(np.float64)])


def eval_q16(z, table_f64=None):
    """PWL evaluation at f64 ``z`` (1-D) → uint32 Q16.

    Bit-identical to rust ``PwlLogistic::eval_q16``: clamp position into
    [0, SEGMENTS], floor to segment index, lerp between padded-f64 table
    endpoints, truncate to u32.
    """
    z = jnp.asarray(z, dtype=jnp.float64)
    table_f = jnp.asarray(TABLE_F64) if table_f64 is None else table_f64
    pos = jnp.clip((z + Z_MAX) * INV_STEP, 0.0, float(SEGMENTS))
    idx = jnp.floor(pos).astype(jnp.int32)  # 0..=SEGMENTS
    frac = pos - idx.astype(jnp.float64)
    # Gather-free segment lookup: one-hot row per lane. The contraction
    # runs in f32 — exact, because the one-hot has a single 1 per row and
    # every table value is an integer ≤ 2^16 (< 2^24) — and converts to
    # f64 only for the lerp, matching the Rust datapath bit-for-bit at
    # half the memory traffic of an f64 one-hot (§Perf L2).
    eq = idx[..., None] == jnp.arange(SEGMENTS + 1, dtype=jnp.int32)
    onehot = jnp.where(eq, 1.0, 0.0).astype(jnp.float32)
    table32 = table_f.astype(jnp.float32)  # exact: integers ≤ 2^16
    a = (onehot @ table32[: SEGMENTS + 1]).astype(jnp.float64)
    b = (onehot @ table32[1 : SEGMENTS + 2]).astype(jnp.float64)
    return (a + (b - a) * frac).astype(jnp.uint32)  # f64 → u32 truncation


def flip_prob_q16(delta_e, temp):
    """Glauber flip probability in Q16 (rust ``flip_prob_q16``).

    ``delta_e`` f64 (integer-valued), ``temp`` f64 scalar or array.
    Handles the T <= 0 zero-temperature limits of Fig. 3.
    """
    return flip_prob_q16_with_table(delta_e, temp, jnp.asarray(TABLE_F64))


def flip_prob_q16_with_table(delta_e, temp, table_f64):
    """`flip_prob_q16` with an explicit table input (pallas kernels must
    receive the table as an argument rather than a captured constant)."""
    delta_e = jnp.asarray(delta_e, dtype=jnp.float64)
    temp = jnp.asarray(temp, dtype=jnp.float64)
    safe_t = jnp.where(temp > 0.0, temp, 1.0)
    # Reciprocal-then-multiply, matching the Rust hot loop bit-for-bit
    # (rust/src/engine/lut.rs::flip_prob_q16_inv).
    interp = eval_q16(delta_e * (1.0 / safe_t), table_f64)
    zero_t = jnp.where(
        delta_e < 0,
        jnp.uint32(ONE_Q16),
        jnp.where(delta_e == 0, jnp.uint32(ONE_Q16 // 2), jnp.uint32(0)),
    )
    return jnp.where(temp > 0.0, interp, zero_t)


# NB: endpoint constants used by eval_q16's domain clamp: TABLE[0] is
# exactly ONE_Q16 and TABLE[-1] exactly 0 for (256 segments, z_max 16) —
# asserted here so a table reconfiguration cannot silently break the
# clamp shortcut above.
assert TABLE[0] == ONE_Q16 and TABLE[-1] == 0
