"""L2 JAX model: the Snowball parallel-mode (roulette-wheel) MCMC chunk.

``anneal_chunk`` advances one chain by C steps inside a single
``lax.scan`` — the XLA realization of Algorithm 1's parallel branch:

  1. per-spin flip probabilities (L1 Pallas PWL kernel, Eq. 25),
  2. aggregate weight W + roulette selection (Eq. 28–30),
  3. W == 0 fallback to a random-scan Glauber update,
  4. deterministic flip + asynchronous incremental field update (Eq. 31).

Every arithmetic step mirrors ``rust/src/engine/snowball.rs`` exactly
(same stateless RNG streams, same Q16 PWL quantization, same prefix-scan
tie-breaking), so a chunked XLA run and the native Rust engine produce
**bit-identical trajectories** — asserted by ``rust/tests/xla_parity.rs``
and ``python/tests/test_model.py``.

Everything is lowered AOT by ``aot.py``; Python never runs at request
time.
"""

import jax
import jax.numpy as jnp

from .kernels import rng_ref as R
from .kernels.flip_probs import flip_probs_q16

ONE_Q16 = 1 << 16


def _mode2_step(j_matrix, carry, inputs):
    """One roulette-wheel step with random-scan fallback (branch-free:
    both candidate selections are computed, `where` picks)."""
    s, u, energy = carry
    temp, stage, seed = inputs
    n = s.shape[0]

    # --- evaluate all lanes through the L1 kernel (Eq. 25) -------------
    p = flip_probs_q16(s, u, temp[None])  # u32[N]
    w = jnp.sum(p.astype(jnp.uint64))

    # --- roulette selection (Eqs. 28–30) --------------------------------
    r = R.draw_below_u64(seed, stage, jnp.maximum(w, R.u64(1)))
    cum = jnp.cumsum(p.astype(jnp.uint64))
    j_roulette = jnp.sum((cum <= r).astype(jnp.int32))
    j_roulette = jnp.minimum(j_roulette, n - 1)

    # --- W == 0 fallback: random-scan Glauber (Eqs. 22/26) --------------
    # All scalar "indexing" below is gather-free (one-hot reductions):
    # xla_extension 0.5.1 mis-executes HLO gather (DESIGN.md
    # §AOT-constraints).
    lanes = jnp.arange(n, dtype=jnp.int32)
    j_fallback = R.rng_below(seed, stage, 0, R.SALT_SITE, n).astype(jnp.int32)
    accept_draw = R.rng_u32(seed, stage, 0, R.SALT_ACCEPT) >> jnp.uint32(16)
    p_fallback = jnp.max(jnp.where(lanes == j_fallback, p, jnp.uint32(0)))
    fallback_accept = accept_draw < p_fallback

    use_roulette = w > R.u64(0)
    chosen = jnp.where(use_roulette, j_roulette, j_fallback)
    do_flip = jnp.where(use_roulette, True, fallback_accept)

    # --- deterministic flip + asynchronous field update (Eq. 31) --------
    onehot_f32 = jnp.where(lanes == chosen, 1.0, 0.0).astype(jnp.float32)
    onehot_f64 = onehot_f32.astype(jnp.float64)
    s_old = jnp.sum(s * onehot_f32)  # exact: single ±1 survives
    u_chosen = jnp.sum(u * onehot_f64)
    de = 2.0 * s_old.astype(jnp.float64) * u_chosen
    flip_f = jnp.where(do_flip, 1.0, 0.0).astype(jnp.float64)
    s_new = s * (1.0 - 2.0 * flip_f.astype(jnp.float32) * onehot_f32)
    # Column stream: one-hot mat-vec extracts row `chosen` of J exactly
    # (J entries are small integers, products exact in f32).
    j_col = (onehot_f32 @ j_matrix).astype(jnp.float64)
    u_new = u - 2.0 * flip_f * s_old.astype(jnp.float64) * j_col
    e_new = energy + flip_f * de

    return (s_new, u_new, e_new), e_new


def anneal_chunk(j_matrix, s, u, energy, temps, seed, step0):
    """Advance the chain by ``temps.shape[0]`` roulette steps.

    j_matrix: f32[N,N] symmetric, zero diagonal
    s:        f32[N] spins (±1)
    u:        f64[N] local fields (h folded in)
    energy:   f64[]  current H(s)
    temps:    f64[C] per-step temperatures
    seed:     u64[]  stateless RNG seed
    step0:    u64[]  global step offset (RNG stage base)
    returns   (s f32[N], u f64[N], energy f64[], trace f64[C])
    """
    c = temps.shape[0]
    stages = R.u64(step0) + jnp.arange(c, dtype=jnp.uint64)
    seeds = jnp.broadcast_to(R.u64(seed), (c,))

    def body(carry, xs):
        return _mode2_step(j_matrix, carry, xs)

    (s, u, energy), trace = jax.lax.scan(body, (s, u, energy), (temps, stages, seeds))
    return s, u, energy, trace


def anneal_chunk_graph(j_matrix, s, u, energy, temps, seed, step0):
    """Tuple-returning wrapper for AOT export."""
    return anneal_chunk(j_matrix, s, u, energy, temps, seed, step0)


def flip_probs_graph(s, u, temp):
    """Standalone L1 kernel graph (exported as its own artifact for the
    runtime microbench and kernel-level parity tests)."""
    return (flip_probs_q16(s, u, temp),)


def field_init_graph(planes_signed, s):
    """Standalone bit-plane field-init graph (L1 kernel artifact)."""
    from .kernels.bitplane_field import field_init

    return (field_init(planes_signed, s),)
