// placeholder
