"""Golden-vector parity: jnp RNG (rng_ref) ≡ python-int RNG (rng_py) ≡
Rust ``rust/src/rng.rs`` (pinned constants).

The three implementations must be bit-identical — the engine/XLA-chunk
trajectory parity (rust/tests/xla_parity.rs) rests on it.
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import rng_py, rng_ref

U64 = st.integers(min_value=0, max_value=(1 << 64) - 1)
SMALL = st.integers(min_value=0, max_value=1 << 20)


def test_mix64_matches_splitmix_reference():
    # Same reference value pinned in rust/src/rng.rs::golden_vectors.
    assert rng_py.mix64(0) == 0xE220A8397B1DCDAF
    assert int(rng_ref.mix64(0)) == 0xE220A8397B1DCDAF


@settings(max_examples=60, deadline=None)
@given(seed=U64, stage=SMALL, it=SMALL, salt=st.integers(0, 7))
def test_u32_jnp_matches_python_int(seed, stage, it, salt):
    assert int(rng_ref.rng_u32(seed, stage, it, salt)) == rng_py.u32(seed, stage, it, salt)


@settings(max_examples=40, deadline=None)
@given(seed=U64, stage=SMALL, it=SMALL, salt=st.integers(0, 7))
def test_u64_jnp_matches_python_int(seed, stage, it, salt):
    assert int(rng_ref.rng_u64(seed, stage, it, salt)) == rng_py.u64(seed, stage, it, salt)


@settings(max_examples=40, deadline=None)
@given(seed=U64, stage=SMALL, n=st.integers(1, 1 << 16))
def test_below_matches(seed, stage, n):
    assert int(rng_ref.rng_below(seed, stage, 0, 1, n)) == rng_py.below(seed, stage, 0, 1, n)


@settings(max_examples=60, deadline=None)
@given(a=U64, b=U64)
def test_mulhi64(a, b):
    assert int(rng_ref.mulhi64(a, b)) == (a * b) >> 64


@settings(max_examples=40, deadline=None)
@given(seed=U64, stage=SMALL, bound=st.integers(1, (1 << 40)))
def test_draw_below(seed, stage, bound):
    assert int(rng_ref.draw_below_u64(seed, stage, bound)) == rng_py.draw_below(seed, stage, bound)
    assert rng_py.draw_below(seed, stage, bound) < bound


@settings(max_examples=30, deadline=None)
@given(seed=U64, idx=SMALL)
def test_child_seed(seed, idx):
    assert int(rng_ref.child_seed(seed, idx)) == rng_py.child_seed(seed, idx)


def test_uniformity_rough():
    vals = [rng_py.u32(7, 0, i, 2) / 2**32 for i in range(20000)]
    assert abs(np.mean(vals) - 0.5) < 0.01
    assert np.min(vals) < 0.01 and np.max(vals) > 0.99


def test_streams_decorrelate_across_salts():
    a = {rng_py.u32(1, 0, i, 1) for i in range(1000)}
    b = {rng_py.u32(1, 0, i, 2) for i in range(1000)}
    assert len(a & b) < 5


@pytest.mark.parametrize("seed", [1, 42, 0x5EED0000_00000001])
def test_golden_vectors_pinned(seed):
    """Pin concrete draws; rust mirrors these in tests (any change to the
    mixing constants breaks this loudly on both sides)."""
    got = [rng_py.u32(seed, 2, i, rng_py.SALT_SITE) for i in range(4)]
    # Self-consistency against the jnp path.
    ref = [int(rng_ref.rng_u32(seed, 2, i, rng_ref.SALT_SITE)) for i in range(4)]
    assert got == ref
