"""L2 model tests: the scan-based anneal chunk vs the per-step oracle,
plus MCMC-level statistical properties."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import ref


def random_instance(rng, n, maxj=3):
    J = rng.integers(-maxj, maxj + 1, (n, n))
    J = np.triu(J, 1)
    J = J + J.T
    s = rng.choice([-1.0, 1.0], n).astype(np.float32)
    u = ref.local_fields_ref(J, np.zeros(n), s)
    e = ref.energy_ref(J, np.zeros(n), s)
    return J, s, u, e


def run_chunk(J, s, u, e, temps, seed, step0):
    fn = jax.jit(model.anneal_chunk_graph)
    return fn(
        jnp.asarray(J, dtype=jnp.float32),
        jnp.asarray(s),
        jnp.asarray(u),
        jnp.asarray(e, dtype=jnp.float64),
        jnp.asarray(temps, dtype=jnp.float64),
        jnp.asarray(seed, dtype=jnp.uint64),
        jnp.asarray(step0, dtype=jnp.uint64),
    )


@pytest.mark.parametrize("n,c", [(8, 16), (32, 40), (64, 64)])
def test_chunk_matches_oracle_bit_exact(n, c):
    rng = np.random.default_rng(n * 13 + c)
    J, s, u, e = random_instance(rng, n)
    temps = np.geomspace(8.0, 0.05, c)
    s1, u1, e1, tr = run_chunk(J, s, u, e, temps, 42, 0)
    rs, ru, re, rtr = ref.anneal_chunk_ref(J, s, u, e, temps, 42, 0)
    assert (np.asarray(s1) == rs).all()
    assert (np.asarray(u1) == ru).all()
    assert float(e1) == re
    assert (np.asarray(tr) == rtr).all()


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(4, 48),
    c=st.integers(1, 32),
    seed=st.integers(0, 2**63 - 1),
    maxj=st.integers(1, 6),
)
def test_chunk_oracle_hypothesis(n, c, seed, maxj):
    rng = np.random.default_rng(seed % (2**31))
    J, s, u, e = random_instance(rng, n, maxj)
    temps = np.geomspace(6.0, 0.1, c)
    s1, u1, e1, tr = run_chunk(J, s, u, e, temps, seed, 0)
    rs, ru, re, rtr = ref.anneal_chunk_ref(J, s, u, e, temps, seed, 0)
    assert (np.asarray(s1) == rs).all()
    assert float(e1) == re


def test_chunking_is_associative():
    """Two chunks of C/2 with step0 continuation == one chunk of C."""
    rng = np.random.default_rng(9)
    J, s, u, e = random_instance(rng, 24)
    temps = np.geomspace(5.0, 0.2, 32)
    s_full, u_full, e_full, _ = run_chunk(J, s, u, e, temps, 7, 0)
    s_a, u_a, e_a, _ = run_chunk(J, s, u, e, temps[:16], 7, 0)
    s_b, u_b, e_b, _ = run_chunk(J, np.asarray(s_a), np.asarray(u_a), float(e_a), temps[16:], 7, 16)
    assert (np.asarray(s_full) == np.asarray(s_b)).all()
    assert float(e_full) == float(e_b)
    assert (np.asarray(u_full) == np.asarray(u_b)).all()


def test_energy_trace_is_consistent():
    rng = np.random.default_rng(3)
    J, s, u, e = random_instance(rng, 32)
    temps = np.geomspace(8.0, 0.05, 64)
    s1, u1, e1, tr = run_chunk(J, s, u, e, temps, 11, 0)
    tr = np.asarray(tr)
    assert tr[-1] == float(e1)
    # Final state self-consistent with the dense energy.
    assert np.isclose(float(e1), ref.energy_ref(J, np.zeros(32), np.asarray(s1)))
    # Cooling run must end below its start energy on a frustrated
    # instance of this size (overwhelmingly likely; seed pinned).
    assert tr[-1] < e


def test_annealing_improves_energy_statistically():
    rng = np.random.default_rng(17)
    J, s, u, e = random_instance(rng, 48, maxj=1)
    temps = np.geomspace(6.0, 0.02, 600)
    finals = []
    for seed in range(5):
        _, _, e1, _ = run_chunk(J, s, u, e, temps, seed, 0)
        finals.append(float(e1))
    assert np.mean(finals) < e - 10


def test_padding_spins_never_selected():
    """Padded lanes (zero couplings, huge positive field) must stay
    frozen — the batcher's invariant (runtime::chunk)."""
    rng = np.random.default_rng(23)
    n_real, n_pad = 24, 8
    J, s, u, e = random_instance(rng, n_real)
    n = n_real + n_pad
    Jp = np.zeros((n, n))
    Jp[:n_real, :n_real] = J
    sp = np.concatenate([s, np.ones(n_pad, np.float32)])
    up = np.concatenate([u, np.full(n_pad, 1e12)])
    temps = np.geomspace(8.0, 0.05, 64)
    s1, u1, e1, _ = run_chunk(Jp, sp, up, e, temps, 5, 0)
    assert (np.asarray(s1)[n_real:] == 1.0).all(), "padding spin flipped"
    assert (np.asarray(u1)[n_real:] == 1e12).all()
