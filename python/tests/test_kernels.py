"""Pallas kernels vs pure-numpy oracles (the core L1 correctness signal),
with hypothesis sweeps over shapes, couplings and temperatures."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import pwl, ref
from compile.kernels.bitplane_field import field_init
from compile.kernels.flip_probs import flip_probs_q16


def random_case(rng, n, umax=30):
    s = rng.choice([-1.0, 1.0], n).astype(np.float32)
    u = rng.integers(-umax, umax + 1, n).astype(np.float64)
    return s, u


# ------------------------------------------------------------- PWL table


def test_table_endpoints_and_monotonicity():
    assert pwl.TABLE[0] == pwl.ONE_Q16
    assert pwl.TABLE[-1] == 0
    assert pwl.TABLE[pwl.SEGMENTS // 2] == pwl.ONE_Q16 // 2  # σ(0) = 1/2
    assert (np.diff(pwl.TABLE.astype(np.int64)) <= 0).all()


def test_pwl_max_error_small():
    zs = np.linspace(-16, 16, 20001)
    approx = ref.flip_probs_ref(np.ones_like(zs), zs / 2.0, 1.0) / pwl.ONE_Q16
    exact = 1.0 / (1.0 + np.exp(zs))
    assert np.abs(approx - exact).max() < 5e-4


# -------------------------------------------------------- flip_probs (L1)


@pytest.mark.parametrize("n", [8, 64, 256, 333, 1024])
@pytest.mark.parametrize("temp", [0.0, 0.05, 1.0, 8.0, 1e6])
def test_flip_probs_kernel_matches_ref(n, temp):
    rng = np.random.default_rng(n * 7 + 1)
    s, u = random_case(rng, n)
    got = np.asarray(flip_probs_q16(jnp.asarray(s), jnp.asarray(u), jnp.asarray([temp])))
    want = ref.flip_probs_ref(s, u, temp)
    assert (got == want).all()


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 300),
    umax=st.integers(1, 5000),
    temp=st.floats(0.001, 1000.0, allow_nan=False),
    seed=st.integers(0, 2**31),
)
def test_flip_probs_hypothesis_sweep(n, umax, temp, seed):
    rng = np.random.default_rng(seed)
    s, u = random_case(rng, n, umax)
    got = np.asarray(flip_probs_q16(jnp.asarray(s), jnp.asarray(u), jnp.asarray([temp])))
    want = ref.flip_probs_ref(s, u, temp)
    assert (got == want).all()


def test_flip_probs_q16_range_and_sign_semantics():
    rng = np.random.default_rng(0)
    s, u = random_case(rng, 128)
    got = np.asarray(flip_probs_q16(jnp.asarray(s), jnp.asarray(u), jnp.asarray([1.0])))
    assert (got <= pwl.ONE_Q16).all()
    de = 2 * s.astype(np.float64) * u
    # Downhill moves more likely than uphill.
    assert got[de < 0].min() >= got[de > 0].max()


# ------------------------------------------------------ field_init (L1)


@pytest.mark.parametrize("n,maxj", [(16, 1), (64, 7), (128, 127), (96, 30000)])
def test_field_init_kernel_matches_ref(n, maxj):
    rng = np.random.default_rng(n)
    J = rng.integers(-maxj, maxj + 1, (n, n))
    J = np.triu(J, 1)
    J = J + J.T
    planes = ref.encode_planes(J)
    s = rng.choice([-1.0, 1.0], n).astype(np.float32)
    got = np.asarray(field_init(jnp.asarray(planes), jnp.asarray(s)))
    want = ref.field_init_ref(planes, s)
    assert (got == want).all()
    # And the planes reconstruct the dense mat-vec exactly (Eq. 16).
    assert np.array_equal(got, J.astype(np.float64) @ s.astype(np.float64))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 80), maxj=st.integers(1, 500), seed=st.integers(0, 2**31))
def test_field_init_hypothesis_sweep(n, maxj, seed):
    rng = np.random.default_rng(seed)
    J = rng.integers(-maxj, maxj + 1, (n, n))
    J = np.triu(J, 1)
    J = J + J.T
    planes = ref.encode_planes(J)
    s = rng.choice([-1.0, 1.0], n).astype(np.float32)
    got = np.asarray(field_init(jnp.asarray(planes), jnp.asarray(s)))
    assert np.array_equal(got, J.astype(np.float64) @ s.astype(np.float64))


def test_encode_planes_roundtrip():
    rng = np.random.default_rng(5)
    J = rng.integers(-100, 101, (32, 32))
    J = np.triu(J, 1)
    J = J + J.T
    planes = ref.encode_planes(J)
    recon = sum((1 << b) * planes[b] for b in range(planes.shape[0]))
    assert np.array_equal(recon, J)


# --------------------------------------------------------------- roulette


def test_roulette_select_matches_rust_semantics():
    p = np.array([0, 10, 0, 5, 1], dtype=np.uint32)
    # cum = [0,10,10,15,16]; first index with cum > r:
    assert ref.roulette_select_ref(p, 0) == 1
    assert ref.roulette_select_ref(p, 9) == 1
    assert ref.roulette_select_ref(p, 10) == 3
    assert ref.roulette_select_ref(p, 14) == 3
    assert ref.roulette_select_ref(p, 15) == 4
