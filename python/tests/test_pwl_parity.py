"""Cross-language golden values for the Q16 PWL Glauber LUT.

The same (ΔE, T) → Q16 pins live in rust
(`rust/src/engine/lut.rs::tests::cross_language_golden_values`), so any
drift in table construction or evaluation order breaks both suites
loudly. jnp path, numpy oracle and pinned literals must all agree.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import pwl, ref
from compile.kernels.flip_probs import flip_probs_q16

# (delta_e, temperature, expected Q16) — keep in sync with the Rust test.
GOLDEN = [
    (2, 1.0, 7812),
    (-2, 1.0, 57724),
    (3, 0.7, 891),
    (0, 5.0, 32768),
    (40, 1.0, 0),
    (-40, 1.0, 65536),
    (1, 0.05, 0),
    (-1, 0.05, 65536),
    (0, 0.0, 32768),
    (-5, 0.0, 65536),
    (5, 0.0, 0),
]


@pytest.mark.parametrize("de,t,expect", GOLDEN)
def test_oracle_matches_golden(de, t, expect):
    s = np.array([1.0], dtype=np.float32)
    u = np.array([de / 2.0], dtype=np.float64)
    assert int(ref.flip_probs_ref(s, u, t)[0]) == expect


@pytest.mark.parametrize("de,t,expect", GOLDEN)
def test_jnp_kernel_matches_golden(de, t, expect):
    s = jnp.asarray([1.0], dtype=jnp.float32)
    u = jnp.asarray([de / 2.0], dtype=jnp.float64)
    got = int(np.asarray(flip_probs_q16(s, u, jnp.asarray([t], dtype=jnp.float64)))[0])
    assert got == expect


def test_table_midpoint_and_padding():
    assert pwl.TABLE[pwl.SEGMENTS // 2] == pwl.ONE_Q16 // 2
    assert len(pwl.TABLE_F64) == pwl.SEGMENTS + 2
    assert pwl.TABLE_F64[-1] == pwl.TABLE_F64[-2]


def test_detailed_balance_ratio_holds_through_q16():
    # P(z)/P(-z) ≈ e^{-z} survives quantization to ~1e-3 (Eq. 8's basis).
    for de, t in [(2, 1.0), (4, 2.0), (1, 0.5)]:
        s = np.array([1.0, -1.0], dtype=np.float32)
        u = np.array([de / 2.0, de / 2.0], dtype=np.float64)
        p = ref.flip_probs_ref(s, u, t).astype(np.float64) / pwl.ONE_Q16
        ratio = p[0] / p[1]
        assert abs(ratio - np.exp(-de / t)) < 2e-3
