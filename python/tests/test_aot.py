"""AOT pipeline tests: lowering produces parseable HLO text with intact
constants, and the manifest is well-formed."""

import os
import subprocess
import sys
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

import pytest

from compile import aot


def test_to_hlo_text_prints_large_constants():
    lowered = aot.lower_flip_probs(64)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # The elided-constant form must never appear (xla 0.5.1 zero-fills it).
    assert "{...}" not in text
    # The Q16 half-point of the PWL table must be literally present.
    assert "32768" in text


def test_lower_anneal_chunk_shapes():
    lowered = aot.lower_anneal_chunk(16, 8)
    text = aot.to_hlo_text(lowered)
    assert "f32[16,16]" in text
    assert "f64[8]" in text  # temps
    assert "u64[]" in text  # seed / step0


def test_quick_emit_writes_manifest():
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ)
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        r = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--quick", "--out-dir", d],
            cwd=repo,
            env=env,
            capture_output=True,
            text=True,
            timeout=600,
        )
        assert r.returncode == 0, r.stderr[-2000:]
        manifest = open(os.path.join(d, "manifest.txt")).read()
        lines = [l for l in manifest.splitlines() if l and not l.startswith("#")]
        assert len(lines) == 3
        for line in lines:
            kv = dict(tok.split("=", 1) for tok in line.split())
            assert {"name", "file", "kind", "n"} <= set(kv)
            assert os.path.exists(os.path.join(d, kv["file"]))


@pytest.mark.parametrize("n,b", [(16, 2), (32, 8)])
def test_lower_field_init(n, b):
    text = aot.to_hlo_text(aot.lower_field_init(n, b))
    assert f"f32[{b},{n},{n}]" in text
